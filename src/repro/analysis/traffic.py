"""Static traffic audit: walk a kernel's jaxpr and count its streams.

The paper's model consumes two code features per kernel — the stream
decomposition (reads / writes / write-allocate RFOs) and the flops per
lattice update — which Table II transcribes by hand.  Kerncraft
(arXiv:1509.03778) showed these features fall out of static analysis of
the loop body; this module is that analysis for the repo's own
jax/pallas kernels, operating on the *closed jaxpr* instead of C source:

* every ``pallas_call`` is decomposed through its ``grid_mapping`` —
  each :class:`BlockMapping`'s index map is analyzed for which grid axes
  it depends on (backward reachability over the index-map jaxpr), which
  yields how often the block is (re)fetched across the sequential grid
  walk and therefore the stream's total element traffic;
* ``scan`` / ``while`` / ``cond`` / ``pjit`` (and the other call-like
  primitives) are recursed into, multiplying trip counts where they are
  static and recording a note where they are not;
* flops are counted per arithmetic primitive (elementwise ops charge
  their output element count, reductions their input count,
  ``dot_general`` the usual ``2·M·N·K``), and ``gather``/``scatter``
  primitives are classified separately from streaming accesses;
* base-buffer provenance is tracked through view primitives (``slice``,
  ``reshape``, ``transpose``, …), so three shifted views of one array —
  the Jacobi up/mid/down rows — are recognized as streams over a single
  base buffer.  :mod:`repro.analysis.features` uses exactly that to
  apply (or refuse) the paper's layer condition.

The result is a :class:`TrafficAudit`: one :class:`Stream` per moved
block plus flop and iteration totals, normalized downstream by
:func:`repro.analysis.features.derive` into the ``LoopFeatures`` that
feed the registry's ECM bridge.
"""

from __future__ import annotations

import dataclasses
import inspect
import math
from typing import Any, Callable, Sequence

from ..core.backend import HAVE_JAX

if HAVE_JAX:
    import jax
    from jax import core as jax_core  # noqa: F401  (Var/Literal live here)

#: Primitives that merely re-view their (first) operand: base-buffer
#: provenance flows through them unchanged.
_VIEW_PRIMS = frozenset({
    "slice", "dynamic_slice", "reshape", "squeeze", "expand_dims",
    "transpose", "rev", "broadcast_in_dim", "convert_element_type",
    "copy", "bitcast_convert_type", "stop_gradient",
})

#: Call-like primitives recursed into with an unchanged trip multiplier.
_CALL_PRIMS = frozenset({
    "pjit", "closed_call", "core_call", "custom_jvp_call",
    "custom_vjp_call", "remat", "checkpoint", "custom_vjp_call_jaxpr",
})

#: flops charged per *output element* for elementwise arithmetic.  Ops
#: that move/select/compare data (select_n, iota, concatenate, pad,
#: comparisons, boolean logic) are deliberately absent: they cost no
#: floating-point work in the paper's accounting.
_ELEMENTWISE_FLOPS = {
    "add": 1, "sub": 1, "mul": 1, "div": 1, "rem": 1, "neg": 1,
    "max": 1, "min": 1, "abs": 1, "sign": 1,
    "exp": 1, "exp2": 1, "log": 1, "log1p": 1, "expm1": 1,
    "sqrt": 1, "rsqrt": 1, "cbrt": 1, "pow": 1, "integer_pow": 1,
    "sin": 1, "cos": 1, "tan": 1, "tanh": 1, "logistic": 1, "erf": 1,
    "atan2": 1, "square": 1, "reciprocal": 1,
    "add_any": 1, "fma": 2,
}

#: Reductions charge their *input* element count (one op per consumed
#: element, the paper's convention for ``s += a[i]``-style loops).
_REDUCE_FLOPS = {
    "reduce_sum": 1, "reduce_prod": 1, "reduce_max": 0, "reduce_min": 0,
    "cumsum": 1, "cumprod": 1, "cumlogsumexp": 2,
}

_GATHER_PRIMS = frozenset({"gather", "dynamic_gather"})
_SCATTER_PRIMS = frozenset({
    "scatter", "scatter-add", "scatter_add", "scatter_mul", "scatter_min",
    "scatter_max",
})


@dataclasses.dataclass(frozen=True)
class Stream:
    """One moved data stream of a kernel call.

    ``elements`` is the total element traffic over the whole call (all
    grid invocations × the block size), *not* per iteration — the
    per-iteration normalization happens in
    :func:`repro.analysis.features.derive`.
    """

    base: str           # source buffer label ("a", "arrays[1]", "<out0>")
    kind: str           # "load" | "store" | "resident" | "accumulator"
    elements: int
    itemsize: int
    fetches: int        # grid invocations that (re)fetch the block
    block_shape: tuple[int, ...]
    aliased: bool = False   # store aliased onto an input (in-place write)
    indexed: str = "affine"  # "affine" | "gather" | "scatter"

    @property
    def bytes(self) -> int:
        return self.elements * self.itemsize


@dataclasses.dataclass(frozen=True)
class TrafficAudit:
    """The walker's verdict on one traced kernel call."""

    name: str
    streams: tuple[Stream, ...]
    flops: float        # total floating-point ops per call
    iters: int          # lattice updates per call (store-stream normalized)
    reductions: int     # cross-grid accumulator outputs
    gathers: int        # gather-indexed accesses seen
    scatters: int
    notes: tuple[str, ...]

    def by_kind(self, kind: str) -> tuple[Stream, ...]:
        return tuple(s for s in self.streams if s.kind == kind)

    @property
    def loads(self) -> tuple[Stream, ...]:
        return self.by_kind("load")

    @property
    def stores(self) -> tuple[Stream, ...]:
        return self.by_kind("store")

    @property
    def total_bytes(self) -> int:
        return sum(s.bytes for s in self.streams
                   if s.kind in ("load", "store"))

    @property
    def flops_per_iter(self) -> float:
        return self.flops / self.iters if self.iters else 0.0


class _State:
    """Mutable accumulator threaded through the walk."""

    def __init__(self) -> None:
        self.streams: list[Stream] = []
        self.flops: float = 0.0
        self.reductions: int = 0
        self.gathers: int = 0
        self.scatters: int = 0
        self.notes: list[str] = []

    def merge(self, other: "_State") -> None:
        self.streams.extend(other.streams)
        self.flops += other.flops
        self.reductions += other.reductions
        self.gathers += other.gathers
        self.scatters += other.scatters
        self.notes.extend(other.notes)

    @property
    def moved_bytes(self) -> int:
        return sum(s.bytes for s in self.streams
                   if s.kind in ("load", "store"))


# ---------------------------------------------------------------------------
# Argument labeling: jaxpr invars -> human-readable base-buffer names
# ---------------------------------------------------------------------------


def _arg_labels(fn: Callable, args: Sequence[Any]) -> list[str]:
    """One label per *flattened* leaf of ``args``, in the order
    ``jax.make_jaxpr`` flattens them, derived from ``fn``'s signature
    (``functools.partial`` is handled by ``inspect``)."""
    names: list[str] = []
    try:
        bound = inspect.signature(fn).bind(*args)
        items = list(bound.arguments.items())
    except (TypeError, ValueError):
        items = [(f"args[{i}]", a) for i, a in enumerate(args)]
    for pname, value in items:
        if isinstance(value, tuple) and not hasattr(value, "shape"):
            sub = [(f"{pname}[{i}]", v) for i, v in enumerate(value)]
        else:
            sub = [(pname, value)]
        for label, v in sub:
            leaves = jax.tree_util.tree_leaves(v)
            if len(leaves) <= 1:
                names.append(label)
            else:
                names.extend(f"{label}.{j}" for j in range(len(leaves)))
    return names


def _base_of(env: dict, atom) -> str:
    """Base label of a jaxpr atom: tracked for vars, synthetic for
    literals/consts."""
    if hasattr(atom, "val"):  # Literal
        return "<lit>"
    return env.get(atom, "<tmp>")


# ---------------------------------------------------------------------------
# Flop counting (shared by the outer walk and pallas kernel bodies)
# ---------------------------------------------------------------------------


def _aval_size(aval) -> int:
    return int(math.prod(getattr(aval, "shape", ()) or (1,)))


def _sub_jaxprs(params: dict):
    """Every (multiplier, jaxpr) pair reachable from an eqn's params."""
    for key in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr"):
        sub = params.get(key)
        if sub is not None:
            yield 1.0, getattr(sub, "jaxpr", sub)
    for branch in params.get("branches", ()) or ():
        yield 1.0, getattr(branch, "jaxpr", branch)


def _count_flops(jaxpr, mult: float, st: _State) -> float:
    """Total flops of one (sub-)jaxpr, recursing into call-like and
    control-flow primitives; also tallies gather/scatter sightings."""
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in _ELEMENTWISE_FLOPS:
            out_elems = sum(_aval_size(v.aval) for v in eqn.outvars)
            total += _ELEMENTWISE_FLOPS[prim] * out_elems * mult
        elif prim in _REDUCE_FLOPS:
            in_elems = _aval_size(eqn.invars[0].aval)
            total += _REDUCE_FLOPS[prim] * in_elems * mult
        elif prim == "dot_general":
            dims = eqn.params["dimension_numbers"]
            (lhs_c, _), _ = dims
            lhs = eqn.invars[0].aval
            k = math.prod(lhs.shape[i] for i in lhs_c) or 1
            out_elems = _aval_size(eqn.outvars[0].aval)
            total += 2.0 * out_elems * k * mult
        elif prim in _GATHER_PRIMS:
            st.gathers += 1
        elif prim in _SCATTER_PRIMS:
            st.scatters += 1
        elif prim == "scan":
            length = float(eqn.params.get("length", 1))
            inner = eqn.params["jaxpr"]
            total += _count_flops(getattr(inner, "jaxpr", inner),
                                  mult * length, st)
        elif prim == "while":
            body = eqn.params["body_jaxpr"]
            total += _count_flops(getattr(body, "jaxpr", body), mult, st)
        elif prim == "cond":
            per_branch = [
                _count_flops(getattr(b, "jaxpr", b), mult, _State())
                for b in eqn.params["branches"]]
            total += max(per_branch, default=0.0)
        else:
            for sub_mult, sub in _sub_jaxprs(eqn.params):
                total += _count_flops(sub, mult * sub_mult, st)
    return total


# ---------------------------------------------------------------------------
# pallas_call decomposition
# ---------------------------------------------------------------------------


def _index_map_deps(index_map_jaxpr, n_axes: int) -> list[int]:
    """Grid axes the block's index map actually reads: backward
    reachability from the index-map outvars to its (grid-index)
    invars."""
    jaxpr = getattr(index_map_jaxpr, "jaxpr", index_map_jaxpr)
    needed = {v for v in jaxpr.outvars if not hasattr(v, "val")}
    changed = True
    while changed:
        changed = False
        for eqn in jaxpr.eqns:
            if any(ov in needed for ov in eqn.outvars):
                for iv in eqn.invars:
                    if not hasattr(iv, "val") and iv not in needed:
                        needed.add(iv)
                        changed = True
    return [i for i, v in enumerate(jaxpr.invars[:n_axes]) if v in needed]


def _block_elems(block_shape) -> int:
    n = 1
    for d in block_shape:
        try:
            n *= max(int(d), 1)
        except (TypeError, ValueError):  # pallas Mapped / squeezed dims
            n *= 1
    return n


def _fetches(deps: Sequence[int], grid: Sequence[int]) -> int:
    """(Re)fetch count of a block over the sequential grid walk: a block
    depending on axes ``deps`` is refetched once per combination of the
    axes up to (and including) its slowest-varying dependence — inner
    independent axes revisit the resident block for free."""
    if not deps:
        return 1
    return int(math.prod(grid[:max(deps) + 1])) or 1


def _audit_pallas(eqn, env: dict, mult: float, st: _State) -> None:
    params = eqn.params
    gm = params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid) or (1,)
    n_axes = len(grid)
    bms = list(gm.block_mappings)
    n_out = int(gm.num_outputs)
    in_bms, out_bms = bms[:len(bms) - n_out], bms[len(bms) - n_out:]

    # Align block-mapped operands with the eqn's invars: scalar-prefetch
    # (index) operands precede them and carry no block mapping.
    invars = list(eqn.invars)
    offset = len(invars) - len(in_bms)
    if offset < 0:  # defensive: never index past the operand list
        offset = 0
    op_invars = invars[offset:]
    for j in range(offset):
        st.notes.append(
            f"pallas scalar-prefetch operand "
            f"{_base_of(env, invars[j])!r} held resident (not a stream)")

    aliases = {}
    for pair in (params.get("input_output_aliases") or ()):
        try:
            i_in, i_out = int(pair[0]), int(pair[1])
        except (TypeError, ValueError, IndexError):
            continue
        aliases[i_out] = i_in

    def _stream(bm, aval, base, is_output, out_idx=None):
        deps = _index_map_deps(bm.index_map_jaxpr, n_axes)
        fetches = _fetches(deps, grid)
        block_shape = tuple(
            d if isinstance(d, int) else 1
            for d in (bm.block_shape or getattr(aval, "shape", ())))
        elements = _block_elems(block_shape) * fetches
        itemsize = int(getattr(getattr(aval, "dtype", None), "itemsize", 4))
        if is_output:
            if not deps:
                st.reductions += 1
                kind = "accumulator"
            else:
                kind = "store"
        else:
            kind = "load" if deps else "resident"
        aliased = False
        if is_output and out_idx is not None and out_idx in aliases:
            a_in = aliases[out_idx] - (len(invars) - len(op_invars))
            if 0 <= a_in < len(op_invars):
                base = _base_of(env, op_invars[a_in])
                aliased = True
        st.streams.append(Stream(
            base=base, kind=kind, elements=int(elements * mult),
            itemsize=itemsize, fetches=int(fetches * mult),
            block_shape=block_shape, aliased=aliased))

    for j, (iv, bm) in enumerate(zip(op_invars, in_bms)):
        _stream(bm, iv.aval, _base_of(env, iv), is_output=False)
    for j, bm in enumerate(out_bms):
        aval = eqn.outvars[j].aval if j < len(eqn.outvars) else None
        _stream(bm, aval, f"<out{j}>", is_output=True, out_idx=j)

    kernel_jaxpr = params.get("jaxpr")
    if kernel_jaxpr is not None:
        invocations = math.prod(grid)
        st.flops += _count_flops(getattr(kernel_jaxpr, "jaxpr",
                                         kernel_jaxpr),
                                 mult * invocations, st)


# ---------------------------------------------------------------------------
# The outer walk
# ---------------------------------------------------------------------------


def _walk(jaxpr, env: dict, mult: float, st: _State) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "pallas_call":
            _audit_pallas(eqn, env, mult, st)
        elif prim == "scan":
            length = float(eqn.params.get("length", 1))
            inner = eqn.params["jaxpr"]
            sub = getattr(inner, "jaxpr", inner)
            sub_env = {iv: _base_of(env, ov)
                       for iv, ov in zip(sub.invars, eqn.invars)}
            _walk(sub, sub_env, mult * length, st)
        elif prim == "while":
            body = eqn.params["body_jaxpr"]
            sub = getattr(body, "jaxpr", body)
            # invars = cond consts + body consts + carry; the body jaxpr
            # sees body consts + carry.
            cc = int(eqn.params.get("cond_nconsts", 0))
            sub_env = {iv: _base_of(env, ov)
                       for iv, ov in zip(sub.invars, eqn.invars[cc:])}
            st.notes.append(
                "while_loop trip count is data-dependent: its body is "
                "counted once (scale the audit by the expected trips)")
            _walk(sub, sub_env, mult, st)
        elif prim == "cond":
            branch_states = []
            for branch in eqn.params["branches"]:
                sub = getattr(branch, "jaxpr", branch)
                sub_env = {iv: _base_of(env, ov)
                           for iv, ov in zip(sub.invars, eqn.invars[1:])}
                bst = _State()
                _walk(sub, sub_env, mult, bst)
                branch_states.append(bst)
            if branch_states:
                worst = max(branch_states, key=lambda b: b.moved_bytes)
                if len(branch_states) > 1:
                    st.notes.append(
                        "cond: counted the heaviest branch "
                        f"({worst.moved_bytes} B of "
                        f"{sorted(b.moved_bytes for b in branch_states)})")
                st.merge(worst)
        elif prim in _CALL_PRIMS:
            for _, sub in _sub_jaxprs(eqn.params):
                sub_env = {iv: _base_of(env, ov)
                           for iv, ov in zip(sub.invars, eqn.invars)}
                _walk(sub, sub_env, mult, st)
                for ov, sv in zip(eqn.outvars, sub.outvars):
                    env[ov] = _base_of(sub_env, sv)
                break
        else:
            if prim in _VIEW_PRIMS and eqn.invars:
                for ov in eqn.outvars:
                    env[ov] = _base_of(env, eqn.invars[0])
            if prim in _ELEMENTWISE_FLOPS or prim in _REDUCE_FLOPS \
                    or prim == "dot_general" or prim in _GATHER_PRIMS \
                    or prim in _SCATTER_PRIMS:
                shim = type("_J", (), {"eqns": [eqn]})()
                st.flops += _count_flops(shim, mult, st)


def _fallback_streams(closed, labels: list[str], st: _State) -> None:
    """No pallas_call anywhere: charge whole-array traffic at the
    jaxpr boundary (consumed invars load, outvars store) so plain-jnp
    functions still audit to something meaningful."""
    jaxpr = closed.jaxpr
    used = set()
    stack = list(jaxpr.eqns)
    while stack:
        eqn = stack.pop()
        used.update(v for v in eqn.invars if not hasattr(v, "val"))
        for _, sub in _sub_jaxprs(eqn.params):
            stack.extend(sub.eqns)
    out_vars = {v for v in jaxpr.outvars if not hasattr(v, "val")}
    for i, iv in enumerate(jaxpr.invars):
        if iv not in used or not getattr(iv.aval, "shape", ()):
            continue
        st.streams.append(Stream(
            base=labels[i] if i < len(labels) else f"args[{i}]",
            kind="load", elements=_aval_size(iv.aval),
            itemsize=int(iv.aval.dtype.itemsize), fetches=1,
            block_shape=tuple(iv.aval.shape)))
    for j, ov in enumerate(jaxpr.outvars):
        if hasattr(ov, "val") or not getattr(ov.aval, "shape", ()):
            continue
        st.streams.append(Stream(
            base=f"<out{j}>", kind="store",
            elements=_aval_size(ov.aval),
            itemsize=int(ov.aval.dtype.itemsize), fetches=1,
            block_shape=tuple(ov.aval.shape),
            aliased=ov in {v for v in jaxpr.invars}))
    st.notes.append("no pallas_call found: streams charged at the "
                    "jaxpr boundary (whole-array traffic)")


def _normalize_iters(streams: Sequence[Stream]) -> int:
    """Lattice updates per call: the largest store stream's element
    count (every Table II kernel writes each site once), falling back
    to the largest load stream for read-only reductions."""
    stores = [s.elements for s in streams if s.kind == "store"]
    if stores:
        return max(stores)
    loads = [s.elements for s in streams if s.kind == "load"]
    return max(loads) if loads else 1


def audit(fn: Callable, *args: Any, name: str | None = None
          ) -> TrafficAudit:
    """Trace ``fn(*args)`` and statically account its memory traffic.

    ``fn`` must be traceable by :func:`jax.make_jaxpr` with the given
    concrete (or shape-struct) arguments; nothing is executed.  Use
    ``functools.partial`` to bind non-traceable arguments (kernel-name
    strings, static configuration).
    """
    if not HAVE_JAX:
        raise RuntimeError(
            "static analysis requires jax (jax.make_jaxpr); it is not "
            "importable in this environment")
    closed = jax.make_jaxpr(fn)(*args)
    labels = _arg_labels(fn, args)
    jaxpr = closed.jaxpr
    env: dict = {}
    for i, iv in enumerate(jaxpr.invars):
        env[iv] = labels[i] if i < len(labels) else f"args[{i}]"
    for cv in jaxpr.constvars:
        env[cv] = "<const>"
    st = _State()
    _walk(jaxpr, env, 1.0, st)
    if not any(s.kind in ("load", "store") for s in st.streams):
        _fallback_streams(closed, labels, st)
    iters = _normalize_iters(st.streams)
    if name is None:
        name = getattr(fn, "__name__", None) or \
            getattr(getattr(fn, "func", None), "__name__", "kernel")
    return TrafficAudit(
        name=name, streams=tuple(st.streams), flops=st.flops,
        iters=iters, reductions=st.reductions, gathers=st.gathers,
        scatters=st.scatters, notes=tuple(dict.fromkeys(st.notes)))
