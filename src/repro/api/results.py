"""Unified result family for the facade: one schema for every engine.

Before the facade callers juggled four incompatible result types —
``SharePrediction`` (scalar tuples), ``BatchSharePrediction`` (arrays),
``TopologyPrediction`` (per-domain mappings), ``BatchRunResult`` (desync
records).  Those stay as the engines' native outputs; this module wraps
them in one schema:

* :class:`Prediction` — one scenario: per-group shares (with the spec
  provenance recorded by :mod:`repro.api.registry`), per-domain
  breakdown, and ``.to_dict()`` / :func:`dump_ndjson` export;
* :class:`BatchPrediction` — B scenarios as batch-first arrays, lazily
  materializing a :class:`Prediction` per row;
* :class:`SimulationResult` — a (possibly ensemble) desync run, with the
  skew/duration/spread analysis helpers next to the records.

Round trip: ``Prediction.from_dict(p.to_dict())`` reproduces every field
(group provenance included), and ndjson files written by
:func:`dump_ndjson` load back with :func:`load_ndjson` — the export
format the "serve millions of scenarios" pipeline logs.
"""

from __future__ import annotations

import dataclasses
import json
from typing import IO, Iterable, Mapping, Sequence

import numpy as np

from ..core.desync import end_spread, start_spread
from ..core.desync_batch import BatchRunResult
from ..core.sharing import BatchSharePrediction, SharePrediction
from ..core.topology import TopologyBatchPrediction

SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class GroupShare:
    """One thread group's slice of a prediction."""

    name: str
    n: int
    f: float
    bs: float
    domain: str            # "" on a single anonymous domain
    provenance: str        # repro.api.registry.PROVENANCES
    alpha: float           # Eq. 5 request share within its domain
    bw: float              # attained bandwidth [GB/s]

    @property
    def bw_per_core(self) -> float:
        return self.bw / self.n if self.n else 0.0


@dataclasses.dataclass(frozen=True)
class DomainShare:
    """One contention domain's aggregate in a prediction."""

    domain: str
    b_overlap: float       # Eq. 4 envelope [GB/s]
    bw: float              # total attained bandwidth [GB/s]


@dataclasses.dataclass(frozen=True)
class Sensitivities:
    """Exact jacobians of a prediction's attained bandwidths.

    ``jacobians[name]`` holds ``∂bw/∂name`` for each requested input
    (``"f"``, ``"b_s"``, ``"cores"``) with the trailing two axes being
    ``(output group, input group)``: a single scenario carries
    ``(G, G)``, a batch ``(B, G, G)``, a placed solve ``(D, K, K)`` /
    ``(B, D, K, K)`` in *grid* coordinates (domain, occupancy slot — the
    same layout as :attr:`PlacedBatchPrediction.grid`).  Produced by
    ``plan.grad(...)`` through :func:`repro.core.sharing.
    solve_arrays_and_grad`; ``softmin_beta`` records whether the
    saturation min was smoothed on the gradient path (None = exact
    subgradient), ``utilization`` the law differentiated through.
    """

    wrt: tuple[str, ...]
    jacobians: Mapping[str, np.ndarray]
    utilization: str | float
    softmin_beta: float | None
    engine: str = "jax"

    def __getitem__(self, name: str) -> np.ndarray:
        try:
            return self.jacobians[name]
        except KeyError:
            from .registry import unknown_key_error
            raise unknown_key_error("gradient input", name,
                                    sorted(self.jacobians)) from None

    def to_dict(self) -> dict:
        return {
            "schema": SCHEMA_VERSION,
            "kind": "sensitivities",
            "wrt": list(self.wrt),
            "utilization": self.utilization,
            "softmin_beta": self.softmin_beta,
            "engine": self.engine,
            "jacobians": {
                name: {"shape": list(j.shape),
                       "data": np.asarray(j).ravel().tolist()}
                for name, j in self.jacobians.items()},
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "Sensitivities":
        jac = {
            name: np.asarray(e["data"], dtype=np.float64).reshape(
                e["shape"])
            for name, e in d["jacobians"].items()}
        return cls(wrt=tuple(d["wrt"]), jacobians=jac,
                   utilization=d["utilization"],
                   softmin_beta=d["softmin_beta"], engine=d["engine"])


@dataclasses.dataclass(frozen=True)
class Prediction:
    """One solved scenario, whichever engine solved it."""

    arch: str
    engine: str            # "scalar" | "topology" | "numpy" | "jax"
    groups: tuple[GroupShare, ...]
    domains: tuple[DomainShare, ...]
    #: Jacobians attached by ``plan.grad(...)``; None on plain solves.
    sensitivities: Sensitivities | None = None

    # -- the classic SharePrediction surface --------------------------------

    @property
    def bw_group(self) -> tuple[float, ...]:
        return tuple(g.bw for g in self.groups)

    @property
    def bw_per_core(self) -> tuple[float, ...]:
        return tuple(g.bw_per_core for g in self.groups)

    @property
    def alphas(self) -> tuple[float, ...]:
        return tuple(g.alpha for g in self.groups)

    @property
    def total_bw(self) -> float:
        return sum(g.bw for g in self.groups)

    @property
    def b_overlap(self) -> float:
        """Eq. 4 envelope.  On a multi-domain prediction this is the
        bandwidth-weighted notion callers usually chart — the sum of the
        populated domains' envelopes; single-domain predictions recover
        the scalar model's number exactly."""
        return sum(d.b_overlap for d in self.domains)

    def domain_bw(self, name: str) -> float:
        for d in self.domains:
            if d.domain == name:
                return d.bw
        from .registry import unknown_key_error
        raise unknown_key_error("domain", name,
                                [d.domain for d in self.domains])

    # -- export -------------------------------------------------------------

    def to_dict(self) -> dict:
        d = {
            "schema": SCHEMA_VERSION,
            "kind": "prediction",
            "arch": self.arch,
            "engine": self.engine,
            "groups": [dataclasses.asdict(g) for g in self.groups],
            "domains": [dataclasses.asdict(d) for d in self.domains],
            "total_bw": self.total_bw,
        }
        if self.sensitivities is not None:
            d["sensitivities"] = self.sensitivities.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "Prediction":
        sens = d.get("sensitivities")
        return cls(
            arch=d["arch"], engine=d["engine"],
            groups=tuple(GroupShare(**g) for g in d["groups"]),
            domains=tuple(DomainShare(**g) for g in d["domains"]),
            sensitivities=(Sensitivities.from_dict(sens)
                           if sens is not None else None))


def _group_shares(pred: SharePrediction, provenance: Sequence[str],
                  domain: str = "") -> tuple[GroupShare, ...]:
    return tuple(
        GroupShare(name=g.name, n=int(g.n), f=g.f, bs=g.bs, domain=domain,
                   provenance=prov, alpha=a, bw=bw)
        for g, prov, a, bw in zip(pred.groups, provenance, pred.alphas,
                                  pred.bw_group))


def from_share_prediction(pred: SharePrediction, *, arch: str,
                          provenance: Sequence[str],
                          engine: str = "scalar") -> Prediction:
    """Wrap a scalar-engine result (floats are copied, not recomputed —
    the facade is bit-for-bit the reference implementation)."""
    dom = DomainShare(domain="", b_overlap=pred.b_overlap,
                      bw=sum(pred.bw_group))
    return Prediction(arch=arch, engine=engine,
                      groups=_group_shares(pred, provenance),
                      domains=(dom,))


def from_topology_prediction(pred, *, arch: str,
                             provenance: Sequence[str]) -> Prediction:
    """Wrap a :class:`repro.core.topology.TopologyPrediction`."""
    alphas: list[float] = []
    for placed in pred.placements:
        sub = pred.by_domain[placed.domain]
        j = sub.groups.index(placed.group)
        alphas.append(sub.alphas[j])
    groups = tuple(
        GroupShare(name=p.group.name, n=int(p.group.n), f=p.group.f,
                   bs=p.group.bs, domain=p.domain, provenance=prov,
                   alpha=a, bw=bw)
        for p, prov, a, bw in zip(pred.placements, provenance, alphas,
                                  pred.bw_group))
    domains = tuple(
        DomainShare(domain=name, b_overlap=pred.by_domain[name].b_overlap,
                    bw=pred.domain_bw(name))
        for name in pred.topology.domain_names)
    return Prediction(arch=arch, engine="topology", groups=groups,
                      domains=domains)


@dataclasses.dataclass(frozen=True)
class BatchPrediction:
    """B solved scenarios, batch-first; each row materializes on demand.

    Scenarios of one batch may target different architectures (the
    arrays carry each row's own ``(f, b_s)`` values): ``archs`` records
    the per-row architecture and every materialized row / export line is
    labelled with its own.
    """

    archs: tuple[str, ...]  # (B,) per-scenario architecture labels
    engine: str            # "numpy" | "jax"
    raw: BatchSharePrediction
    provenance: tuple[tuple[str, ...], ...]  # (B, G), "" for padding
    #: Jacobians attached by ``plan.grad(...)`` — ``(B, G, G)`` per
    #: input; None on plain solves.
    sensitivities: Sensitivities | None = None

    @property
    def arch(self) -> str:
        """The batch's architecture, ``"mixed"`` when rows differ."""
        return self.archs[0] if len(set(self.archs)) == 1 else "mixed"

    # Array surface (delegates to the engine's native result).

    @property
    def bw_group(self) -> np.ndarray:
        return self.raw.bw_group

    @property
    def bw_per_core(self) -> np.ndarray:
        return self.raw.bw_per_core

    @property
    def alphas(self) -> np.ndarray:
        return self.raw.alphas

    @property
    def b_overlap(self) -> np.ndarray:
        return self.raw.b_overlap

    @property
    def total_bw(self) -> np.ndarray:
        return self.raw.total_bw

    def __len__(self) -> int:
        return len(self.raw)

    def __getitem__(self, i: int) -> Prediction:
        # Keep groups by provenance, not by n > 0: a scenario's genuine
        # n = 0 group (neutral in Eqs. 4–5 but present in the scalar
        # result) is indistinguishable from padding in the arrays alone.
        prov_row = self.provenance[i]
        keep = [j for j, p in enumerate(prov_row) if p]
        raw = self.raw
        groups = tuple(
            GroupShare(
                name=(raw.names[i][j] if raw.names is not None else ""),
                n=int(raw.n[i, j]), f=float(raw.f[i, j]),
                bs=float(raw.bs[i, j]), domain="",
                provenance=prov_row[j],
                alpha=float(raw.alphas[i, j]),
                bw=float(raw.bw_group[i, j]))
            for j in keep)
        dom = DomainShare(domain="", b_overlap=float(raw.b_overlap[i]),
                          bw=sum(g.bw for g in groups))
        return Prediction(arch=self.archs[i], engine=self.engine,
                          groups=groups, domains=(dom,))

    def __iter__(self):
        return (self[i] for i in range(len(self)))

    def rows(self, limit: int | None = None) -> "list[Prediction]":
        """Materialize the first ``limit`` rows (all by default) in one
        pass.  Same values as ``[self[i] for i in ...]``, but the arrays
        are converted to Python scalars with one bulk ``tolist()`` per
        field instead of a numpy scalar per element — the difference
        between ~13 us and ~4 us per row, which is what the serving
        fan-out pays on every coalesced tick."""
        B = len(self) if limit is None else min(limit, len(self))
        raw = self.raw
        n_l = np.asarray(raw.n)[:B].tolist()
        f_l = np.asarray(raw.f)[:B].tolist()
        bs_l = np.asarray(raw.bs)[:B].tolist()
        alpha_l = np.asarray(raw.alphas)[:B].tolist()
        bw_l = np.asarray(raw.bw_group)[:B].tolist()
        env_l = np.asarray(raw.b_overlap)[:B].tolist()
        names = raw.names
        engine = self.engine
        # Instances are built via __new__ + __dict__.update: the frozen
        # dataclasses store fields in __dict__, and their generated
        # __init__ pays one object.__setattr__ per field — ~3x the cost
        # of this path, per group, per row, per tick when serving.
        gs_new, ds_new = GroupShare.__new__, DomainShare.__new__
        pr_new = Prediction.__new__
        out = []
        for i in range(B):
            prov_row = self.provenance[i]
            ni, fi, bsi = n_l[i], f_l[i], bs_l[i]
            ai, bwi = alpha_l[i], bw_l[i]
            nmi = names[i] if names is not None else None
            groups = []
            bw_sum = 0.0
            for j, p in enumerate(prov_row):
                if not p:
                    continue
                g = gs_new(GroupShare)
                g.__dict__.update(
                    name=(nmi[j] if nmi is not None else ""),
                    n=int(ni[j]), f=fi[j], bs=bsi[j], domain="",
                    provenance=p, alpha=ai[j], bw=bwi[j])
                bw_sum += bwi[j]
                groups.append(g)
            dom = ds_new(DomainShare)
            dom.__dict__.update(domain="", b_overlap=env_l[i], bw=bw_sum)
            pred = pr_new(Prediction)
            pred.__dict__.update(
                arch=self.archs[i], engine=engine,
                groups=tuple(groups), domains=(dom,),
                sensitivities=None)
            out.append(pred)
        return out

    def iter_dicts(self):
        """Lazily yield one export dict per scenario — a
        million-scenario batch streams through one row of working set
        instead of one giant list (the ndjson writers are built on
        this)."""
        return (self[i].to_dict() for i in range(len(self)))

    def to_dicts(self) -> list[dict]:
        return list(self.iter_dicts())


@dataclasses.dataclass(frozen=True)
class PlacedBatchPrediction:
    """B placed-topology solves from one flattened grid solve.

    The array surface exposes the solver's padded ``(B, D, K)`` grid
    (``D`` topology domains, up to ``K`` groups each, masked occupancy);
    indexing materializes row *i* as exactly the :class:`Prediction` a
    lone placed ``predict`` would have returned — on the numpy backend
    bit-for-bit, since padded grid cells are exactly neutral.
    """

    archs: tuple[str, ...]   # (B,) per-scenario architecture labels
    engine: str              # solver backend: "numpy" | "jax"
    raw: TopologyBatchPrediction
    provenance: tuple[tuple[str, ...], ...]  # (B, J) input-order labels
    #: Jacobians attached by ``plan.grad(...)`` — ``(B, D, K, K)`` in
    #: grid coordinates; None on plain solves.
    sensitivities: Sensitivities | None = None

    @property
    def arch(self) -> str:
        return self.archs[0] if len(set(self.archs)) == 1 else "mixed"

    @property
    def topology(self):
        return self.raw.topology

    # Array surface (the solver's native padded-grid result).

    @property
    def bw_group(self) -> tuple[tuple[float, ...], ...]:
        """Per scenario, attained bandwidths in input placement order."""
        return self.raw.bw_group

    @property
    def total_bw(self) -> np.ndarray:
        return self.raw.total_bw

    @property
    def grid(self):
        """The padded ``(B, D, K)`` solver result
        (:class:`repro.core.sharing.PlacedBatchSharePrediction`)."""
        return self.raw.shares

    def __len__(self) -> int:
        return len(self.raw)

    def __getitem__(self, i: int) -> Prediction:
        return from_topology_prediction(
            self.raw.scenario(i), arch=self.archs[i],
            provenance=self.provenance[i])

    def __iter__(self):
        return (self[i] for i in range(len(self)))

    def iter_dicts(self):
        """Lazily yield one export dict per scenario (row-at-a-time
        working set, matching :meth:`BatchPrediction.iter_dicts`)."""
        return (self[i].to_dict() for i in range(len(self)))

    def to_dicts(self) -> list[dict]:
        return list(self.iter_dicts())


@dataclasses.dataclass(frozen=True)
class SimulationResult:
    """A desync run (B noise draws / candidates × R ranks), unified."""

    arch: str
    engine: str            # "desync-numpy" | "desync-jax"
    raw: BatchRunResult
    #: Flattened-row origin of a fused batch×ensemble run:
    #: ``members[b] == (scenario_index, member_index)``.  None when the
    #: run was not ensemble-expanded (every row is its own scenario).
    members: tuple[tuple[int, int], ...] | None = None

    def rows_for(self, scenario: int) -> tuple[int, ...]:
        """Flattened row indices of one input scenario's ensemble
        members (``(scenario,)`` itself when the run is unfused)."""
        if self.members is None:
            return (scenario,)
        return tuple(b for b, (s, _) in enumerate(self.members)
                     if s == scenario)

    @property
    def n_scenarios(self) -> int:
        return self.raw.n_scenarios

    @property
    def n_ranks(self) -> int:
        return self.raw.n_ranks

    @property
    def t_end(self) -> np.ndarray:
        return self.raw.t_end

    @property
    def failed(self) -> np.ndarray:
        return self.raw.failed

    def records(self, b: int = 0):
        return self.raw.records[b]

    def makespan(self, b: int = 0) -> float:
        return max((r.end for r in self.raw.records[b]), default=0.0)

    def durations(self, tag: str, b: int = 0, **kwargs) -> list[float]:
        return self.raw.durations_by_tag(b, tag, **kwargs)

    def skew(self, tag: str) -> np.ndarray:
        """Per-scenario Fisher skewness of accumulated ``tag`` time (the
        paper's desync indicator); NaN for deadlocked scenarios."""
        return self.raw.skew_by_tag(tag)

    def mean_skew(self, tag: str) -> float:
        return float(self.skew(tag).mean())

    def start_spread(self, tag: str, b: int = 0) -> float:
        return start_spread(self.raw.records[b], tag)

    def end_spread(self, tag: str, b: int = 0) -> float:
        return end_spread(self.raw.records[b], tag)

    def to_dict(self, *, tags: Sequence[str] = ()) -> dict:
        d = {
            "schema": SCHEMA_VERSION,
            "kind": "simulation",
            "arch": self.arch,
            "engine": self.engine,
            "n_scenarios": self.n_scenarios,
            "n_ranks": self.n_ranks,
            "n_events": self.raw.n_events,
            "n_failed": self.raw.n_failed,
            "t_end": [float(t) for t in self.t_end],
        }
        if tags:
            d["skew"] = {t: [float(x) for x in self.skew(t)]
                         for t in tags}
        return d


# ---------------------------------------------------------------------------
# ndjson export / import
# ---------------------------------------------------------------------------


def iter_ndjson(results: Iterable[Prediction | BatchPrediction]
                ) -> "Iterable[str]":
    """Lazily yield one serialized JSON line per *scenario* (batches
    are flattened through :meth:`BatchPrediction.iter_dicts`, one row
    of working set at a time) — the streaming half of
    :func:`dump_ndjson`, for callers that pipe lines elsewhere."""
    for res in results:
        rows = res.iter_dicts() \
            if isinstance(res, (BatchPrediction, PlacedBatchPrediction)) \
            else [res.to_dict()]
        for row in rows:
            yield json.dumps(row, sort_keys=True)


def dump_dicts(rows: Iterable[Mapping], fh: IO[str]) -> int:
    """Stream arbitrary dict records as ndjson lines (one write per
    record, nothing accumulated).  Returns the line count.  The
    benchmark driver's ``--ndjson`` mode uses this."""
    n = 0
    for row in rows:
        fh.write(json.dumps(row, sort_keys=True) + "\n")
        n += 1
    return n


def dump_ndjson(results: Iterable[Prediction | BatchPrediction],
                fh: IO[str]) -> int:
    """Write one JSON line per *scenario* (batches are flattened and
    streamed row by row — a million-scenario batch never materializes
    one giant list).  Returns the number of lines written."""
    n = 0
    for line in iter_ndjson(results):
        fh.write(line + "\n")
        n += 1
    return n


def load_ndjson(fh: IO[str]) -> list[Prediction]:
    """Load predictions written by :func:`dump_ndjson`."""
    out = []
    for line in fh:
        line = line.strip()
        if not line:
            continue
        d = json.loads(line)
        if d.get("kind") != "prediction":
            raise ValueError(
                f"ndjson line is not a prediction (kind="
                f"{d.get('kind')!r})")
        out.append(Prediction.from_dict(d))
    return out
