"""Train/serve step builders: jit-compiled, sharded, donation-aware.

``build_train_step`` returns a function
    (train_state, batch) -> (train_state, metrics)
with AdamW fused in, optional microbatch gradient accumulation (lax.scan),
and optional int8 error-feedback compression applied to the cross-pod
gradient reduction (the "pod" mesh axis) — the sharding-model-guided
distributed-optimization path.

``build_serve_step`` returns (params, cache, tokens, pos) -> (logits, cache)
with the cache donated (decode is in-place on device).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models import Model
from ..optim import adamw_init, adamw_update
from . import sharding as shard_rules


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array


def init_train_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def train_state_shardings(cfg: ModelConfig, mesh: Mesh, state_shape,
                          *, fsdp: bool | None = None,
                          dp_only: bool = False):
    pshard = shard_rules.param_shardings(cfg, mesh, state_shape.params,
                                         fsdp=fsdp, dp_only=dp_only)
    return TrainState(
        params=pshard,
        opt=dataclasses.replace(
            state_shape.opt,
            step=NamedSharding(mesh, P()),
            m=jax.tree.map(lambda s: s, pshard),
            v=jax.tree.map(lambda s: s, pshard),
        ),
        step=NamedSharding(mesh, P()),
    )


def build_train_step(model: Model, *, lr_fn: Callable,
                     microbatches: int = 1, weight_decay: float = 0.1,
                     clip_norm: float = 1.0,
                     mb_shardings: Any = None) -> Callable:
    cfg = model.cfg

    def compute_grads(params, batch):
        def scalar_loss(p, b):
            loss, metrics = model.loss(p, b)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(
            scalar_loss, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state: TrainState, batch):
        if microbatches > 1:
            # Unrolled accumulation (not lax.scan): XLA frees each
            # microbatch's activations before the next starts, the gradient
            # buffers are add-accumulated in place, and — unlike a while
            # loop — the cost analysis of the compiled module stays exact.
            #
            # Microbatches are carved out by RESHAPING to a leading
            # unsharded axis (B,) -> (N, B/N): slicing the *sharded* batch
            # axis instead makes SPMD reshard every microbatch (measured:
            # ~3.5x flop inflation on a 16-wide data axis).
            def split_mb(k, x):
                y = x.reshape(microbatches, x.shape[0] // microbatches,
                              *x.shape[1:])
                if mb_shardings is not None and k in mb_shardings:
                    y = jax.lax.with_sharding_constraint(y, mb_shardings[k])
                return y

            split = {k: split_mb(k, v) for k, v in batch.items()}
            grads = None
            losses = []
            for i in range(microbatches):
                mb = {k: v[i] for k, v in split.items()}
                loss_i, _, g_i = compute_grads(state.params, mb)
                losses.append(loss_i)
                grads = g_i if grads is None else jax.tree.map(
                    jnp.add, grads, g_i)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = jnp.mean(jnp.stack(losses))
            metrics = {"lm_loss": loss}
        else:
            loss, metrics, grads = compute_grads(state.params, batch)

        params, opt = adamw_update(
            grads, state.opt, state.params, lr=lr_fn(state.step),
            weight_decay=weight_decay, clip_norm=clip_norm)
        new_state = TrainState(params=params, opt=opt, step=state.step + 1)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["lr"] = lr_fn(state.step)
        return new_state, metrics

    return train_step


def jit_train_step(model: Model, mesh: Mesh, state_shape, batch_specs, *,
                   lr_fn, microbatches: int = 1, fsdp: bool | None = None,
                   dp_only: bool = False):
    """jit with explicit in/out shardings; donates the state."""
    state_sh = train_state_shardings(model.cfg, mesh, state_shape, fsdp=fsdp,
                                     dp_only=dp_only)
    batch_sh = shard_rules.batch_shardings(mesh, batch_specs,
                                           dp_only=dp_only)
    mb_sh = None
    if microbatches > 1:
        mb_sh = {}
        for k, s in batch_sh.items():
            spec = s.spec
            mb_sh[k] = NamedSharding(mesh, P(None, *spec))
    step_fn = build_train_step(model, lr_fn=lr_fn, microbatches=microbatches,
                               mb_shardings=mb_sh)
    metric_sh = NamedSharding(mesh, P())
    return jax.jit(
        step_fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    ), state_sh, batch_sh


def build_serve_step(model: Model):
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = model.decode_step(params, cache, tokens, pos)
        return logits, new_cache
    return serve_step


def jit_serve_step(model: Model, mesh: Mesh, params_shape, cache_shape, *,
                   batch: int, fsdp: bool | None = None):
    serve = build_serve_step(model)
    pshard = shard_rules.param_shardings(model.cfg, mesh, params_shape,
                                         fsdp=fsdp)
    cshard = shard_rules.cache_shardings(model.cfg, mesh, cache_shape)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tok_sh = NamedSharding(
        mesh, P(dp) if batch % shard_rules._axis_size(mesh, dp) == 0 else P())
    return jax.jit(
        serve,
        in_shardings=(pshard, cshard, tok_sh, tok_sh),
        out_shardings=(None, cshard),
        donate_argnums=(1,),
    ), pshard, cshard, tok_sh
