"""Pallas interpret-mode vs oracle: flash attention, decode attention,
jacobi stencils, rmsnorm."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops


def _qkv(b, h, kv, s, d, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype) * 0.3
    k = jnp.asarray(rng.standard_normal((b, kv, s, d)), dtype) * 0.3
    v = jnp.asarray(rng.standard_normal((b, kv, s, d)), dtype) * 0.3
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 2, 2, 128, 64),      # MHA
    (2, 4, 2, 256, 64),      # GQA group 2
    (1, 8, 1, 128, 128),     # MQA
])
def test_flash_attention_matches_ref(b, h, kv, s, d, causal):
    q, k, v = _qkv(b, h, kv, s, d)
    got = ops.attention(q, k, v, causal=causal, impl="interpret",
                        block_q=64, block_k=64)
    want = ops.attention(q, k, v, causal=causal, impl="jnp")
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@given(bq=st.sampled_from([32, 64, 128]), bk=st.sampled_from([32, 64, 128]),
       s=st.sampled_from([128, 256]))
@settings(max_examples=10, deadline=None)
def test_flash_attention_block_sweep(bq, bk, s):
    q, k, v = _qkv(1, 2, 1, s, 64, seed=s + bq)
    got = ops.attention(q, k, v, causal=True, impl="interpret",
                        block_q=bq, block_k=bk)
    want = ops.attention(q, k, v, causal=True, impl="jnp")
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    q, k, v = _qkv(1, 2, 2, 128, 64, dtype=jnp.bfloat16)
    got = ops.attention(q, k, v, causal=True, impl="interpret",
                        block_q=64, block_k=64)
    want = ops.attention(q, k, v, causal=True, impl="jnp")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("b,h,kv,s,d,blk", [
    (2, 4, 2, 512, 64, 128),
    (1, 8, 8, 256, 64, 256),
    (3, 4, 1, 1024, 128, 512),
])
def test_decode_attention_matches_ref(b, h, kv, s, d, blk):
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32) * 0.3
    kc = jnp.asarray(rng.standard_normal((b, kv, s, d)), jnp.float32) * 0.3
    vc = jnp.asarray(rng.standard_normal((b, kv, s, d)), jnp.float32) * 0.3
    lengths = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    got = ops.decode_attention(q, kc, vc, lengths, impl="interpret",
                               block_k=blk)
    want = ops.decode_attention(q, kc, vc, lengths, impl="jnp")
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_decode_attention_length_masking():
    """Entries beyond lengths[b] must not affect the result."""
    b, h, kv, s, d = 2, 4, 2, 256, 64
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, kv, s, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, kv, s, d)), jnp.float32)
    lengths = jnp.asarray([100, 17], jnp.int32)
    base = ops.decode_attention(q, kc, vc, lengths, impl="interpret",
                                block_k=128)
    kc2 = kc.at[:, :, 200:].set(1e4)
    vc2 = vc.at[:, :, 200:].set(-1e4)
    poisoned = ops.decode_attention(q, kc2, vc2, lengths, impl="interpret",
                                    block_k=128)
    np.testing.assert_allclose(base, poisoned, rtol=1e-6)


# --------------------------------------------------------------------------
# Jacobi
# --------------------------------------------------------------------------


@pytest.mark.parametrize("h,w", [(18, 128), (66, 256), (130, 384)])
def test_jacobi_v1_matches_ref(h, w):
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal((h, w)), jnp.float32)
    got = ops.jacobi_v1(a, 0.25, impl="interpret")
    want = ops.jacobi_v1(a, 0.25, impl="jnp")
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("h,w", [(18, 128), (34, 256)])
def test_jacobi_v2_matches_ref(h, w):
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.standard_normal((h, w)), jnp.float32)
    f = jnp.asarray(rng.standard_normal((h, w)), jnp.float32)
    kw = dict(ax=0.4, ay=0.6, b1=2.0, relax=0.9)
    got_b, got_r = ops.jacobi_v2(a, f, impl="interpret", **kw)
    want_b, want_r = ops.jacobi_v2(a, f, impl="jnp", **kw)
    np.testing.assert_allclose(got_b, want_b, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-4)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------


@pytest.mark.parametrize("shape,hidden", [((4, 64), 512), ((2, 16), 1024),
                                          ((128,), 896)])
def test_rmsnorm_matches_ref(shape, hidden):
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((*shape, hidden)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(hidden), jnp.float32)
    got = ops.rmsnorm(x, w, impl="interpret")
    want = ops.rmsnorm(x, w, impl="jnp")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_rmsnorm_residual_matches_ref():
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.standard_normal((8, 32, 896)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((8, 32, 896)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(896), jnp.float32)
    got_y, got_h = ops.rmsnorm_residual(x, r, w, impl="interpret")
    want_y, want_h = ops.rmsnorm_residual(x, r, w, impl="jnp")
    np.testing.assert_allclose(got_y, want_y, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_h, want_h, rtol=1e-6)
