"""Whisper-style encoder-decoder backbone (whisper-tiny assignment).

The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, n_audio_frames, d_model) — the
two conv layers that produce them are out of scope.  Everything after that
is implemented: sinusoidal positions, pre-LN encoder (bidirectional MHA),
decoder (causal self-attn + cross-attn), GELU MLPs, LayerNorm.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers


def _sinusoid(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / (d // 2))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def enc_layer_params(cfg: ModelConfig, key):
    ka, km = jax.random.split(key)
    return {
        "ln1": layers.norm_params(cfg),
        "attn": layers.attention_params(cfg, ka),
        "ln2": layers.norm_params(cfg),
        "mlp": layers.mlp_params(cfg, km),
    }


def dec_layer_params(cfg: ModelConfig, key):
    ka, kx, km = jax.random.split(key, 3)
    return {
        "ln1": layers.norm_params(cfg),
        "self": layers.attention_params(cfg, ka),
        "ln_x": layers.norm_params(cfg),
        "cross": layers.attention_params(cfg, kx),
        "ln2": layers.norm_params(cfg),
        "mlp": layers.mlp_params(cfg, km),
    }


def init_params(cfg: ModelConfig, key):
    ke, kenc, kdec = jax.random.split(key, 3)
    n_enc = cfg.enc_layers or cfg.n_layers
    enc_keys = jax.random.split(kenc, n_enc)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": layers.embed_init(ke, cfg.vocab, cfg.d_model,
                                   jnp.dtype(cfg.param_dtype)),
        "enc": jax.vmap(functools.partial(enc_layer_params, cfg))(enc_keys),
        "enc_ln_f": layers.norm_params(cfg),
        "dec": jax.vmap(functools.partial(dec_layer_params, cfg))(dec_keys),
        "ln_f": layers.norm_params(cfg),
    }


# --------------------------------------------------------------------------
# Cross attention (no RoPE, encoder-side KV)
# --------------------------------------------------------------------------


def _cross_attention(cfg: ModelConfig, p, x, enc_kv):
    b, s, d = x.shape
    hd = cfg.head_dim_
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, cfg.n_heads, hd)
    k, v = enc_kv
    out = layers._sdpa(cfg, q, k, v, causal=False, cross=True)
    return out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def _enc_kv(cfg: ModelConfig, p, enc_out):
    b, t, _ = enc_out.shape
    hd = cfg.head_dim_
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(
        b, t, cfg.kv_heads, hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(
        b, t, cfg.kv_heads, hd)
    return k, v


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def encode(cfg: ModelConfig, params, frames):
    """frames: (B, T, D) stub embeddings -> encoder states (B, T, D)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(x.shape[1])[None, :]

    def body(lp, x):
        h = layers.apply_norm(cfg, lp["ln1"], x)
        x = x + layers.attention(cfg, lp["attn"], h, positions, causal=False)
        h = layers.apply_norm(cfg, lp["ln2"], x)
        return x + layers.apply_mlp(cfg, lp["mlp"], h)

    if cfg.remat:
        body = layers.remat(cfg, body)

    if cfg.use_scan:
        x, _ = jax.lax.scan(lambda c, lp: (body(lp, c), None), x,
                            params["enc"])
    else:
        for i in range(cfg.enc_layers or cfg.n_layers):
            x = body(jax.tree.map(lambda a: a[i], params["enc"]), x)
    return layers.apply_norm(cfg, params["enc_ln_f"], x)


def decode(cfg: ModelConfig, params, tokens, enc_out):
    """Teacher-forced decoder: tokens (B, S) -> logits."""
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(lp, x):
        h = layers.apply_norm(cfg, lp["ln1"], x)
        x = x + layers.attention(cfg, lp["self"], h, positions, causal=True)
        h = layers.apply_norm(cfg, lp["ln_x"], x)
        x = x + _cross_attention(cfg, lp["cross"], h,
                                 _enc_kv(cfg, lp["cross"], enc_out))
        h = layers.apply_norm(cfg, lp["ln2"], x)
        return x + layers.apply_mlp(cfg, lp["mlp"], h)

    if cfg.remat:
        body = layers.remat(cfg, body)

    if cfg.use_scan:
        x, _ = jax.lax.scan(lambda c, lp: (body(lp, c), None), x,
                            params["dec"])
    else:
        for i in range(cfg.n_layers):
            x = body(jax.tree.map(lambda a: a[i], params["dec"]), x)
    x = layers.apply_norm(cfg, params["ln_f"], x)
    return layers.unembed(cfg, params["embed"], x)


def forward(cfg: ModelConfig, params, batch):
    enc_out = encode(cfg, params, batch["frames"])
    return decode(cfg, params, batch["tokens"], enc_out)


def loss_fn(cfg: ModelConfig, params, batch):
    logits = forward(cfg, params, batch)
    labels = batch["labels"]
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss, {"lm_loss": loss}


# --------------------------------------------------------------------------
# Decode (incremental, with self-KV cache + precomputed cross-KV)
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
               enc_out=None, params=None):
    hd = cfg.head_dim_
    dt = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, batch, max_seq, cfg.kv_heads, hd)
    cache = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    if enc_out is not None:
        xk, xv = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["dec"])
            k, v = _enc_kv(cfg, lp["cross"], enc_out)
            xk.append(k)
            xv.append(v)
        cache["xk"] = jnp.stack(xk)
        cache["xv"] = jnp.stack(xv)
    else:
        t = cfg.n_audio_frames
        cache["xk"] = jnp.zeros((cfg.n_layers, batch, t, cfg.kv_heads, hd),
                                dt)
        cache["xv"] = jnp.zeros((cfg.n_layers, batch, t, cfg.kv_heads, hd),
                                dt)
    return cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    x = params["embed"][tokens[:, None]].astype(jnp.dtype(cfg.dtype))
    pe = _sinusoid(int(cache["k"].shape[2]), cfg.d_model).astype(x.dtype)
    x = x + pe[pos][:, None]

    def body(carry, inp):
        x = carry
        lp, ck, cv, xk, xv = inp
        h = layers.apply_norm(cfg, lp["ln1"], x)
        a, ck, cv = layers.attention_decode(cfg, lp["self"], h, ck, cv, pos)
        x = x + a
        h = layers.apply_norm(cfg, lp["ln_x"], x)
        x = x + _cross_attention(cfg, lp["cross"], h, (xk, xv))
        h = layers.apply_norm(cfg, lp["ln2"], x)
        x = x + layers.apply_mlp(cfg, lp["mlp"], h)
        return x, (ck, cv)

    if cfg.use_scan:
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
    else:
        ks_l, vs_l = [], []
        for i in range(cfg.n_layers):
            inp = jax.tree.map(lambda a: a[i],
                               (params["dec"], cache["k"], cache["v"],
                                cache["xk"], cache["xv"]))
            x, (ck, cv) = body(x, inp)
            ks_l.append(ck)
            vs_l.append(cv)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)
    x = layers.apply_norm(cfg, params["ln_f"], x)
    logits = layers.unembed(cfg, params["embed"], x)[:, 0]
    return logits, {**cache, "k": ks, "v": vs}
