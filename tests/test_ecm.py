"""Tests for the ECM model (paper Eqs. 1-3) and the multicore scaling curve."""

import pytest

from repro.core import ecm, table2
from repro.core.machine import X86_MACHINES


@pytest.mark.parametrize("arch", sorted(X86_MACHINES))
@pytest.mark.parametrize("name", ["DDOT2", "DCOPY", "STREAM", "Schoenauer"])
def test_f_prediction_in_range(arch, name):
    spec = table2.kernel(name)
    pred = ecm.predict(spec, X86_MACHINES[arch])
    assert 0.0 < pred.f <= 1.0


def test_rome_overlap_composition():
    """Rome's overlapping hierarchy makes streaming kernels memory-bound with
    f -> 1 (paper: 'on AMD Rome ... it is often close to one')."""
    spec = table2.kernel("STREAM")
    pred = ecm.predict(spec, X86_MACHINES["ROME"])
    assert pred.f > 0.9


def test_intel_serial_composition():
    """Intel's non-overlapping transfers keep f well below one (Eq. 1)."""
    spec = table2.kernel("STREAM")
    for arch in ("BDW-1", "BDW-2", "CLX"):
        pred = ecm.predict(spec, X86_MACHINES[arch])
        assert pred.f < 0.6
        # Serial composition: T_ECM >= T_Mem + caches + L1Reg.
        assert pred.t_ecm == pytest.approx(
            pred.t_mem + sum(pred.t_cache) + pred.t_l1reg)


def test_ecm_f_ordering_matches_table():
    """The analytic path need not match measured f absolutely (a global
    factor cancels in Eq. 5 — paper Sect. V), but the ordering across
    kernels must agree."""
    for arch in ("BDW-1", "BDW-2", "CLX"):
        m = X86_MACHINES[arch]
        f_ecm = {n: ecm.predict(table2.kernel(n), m).f
                 for n in ("DDOT2", "DCOPY", "DSCAL")}
        f_tab = {n: table2.kernel(n).f[arch] for n in f_ecm}
        assert (f_ecm["DSCAL"] > f_ecm["DDOT2"]) == (
            f_tab["DSCAL"] > f_tab["DDOT2"])
        assert (f_ecm["DCOPY"] > f_ecm["DDOT2"]) == (
            f_tab["DCOPY"] > f_tab["DDOT2"])


def test_scaling_curve_saturates():
    u = ecm.scaling_curve(f=0.25, t_mem=0.25, t_ecm=1.0, n_max=32)
    assert u[0] == pytest.approx(0.25)
    assert all(b >= a - 1e-12 for a, b in zip(u, u[1:]))  # monotone
    assert u[-1] == pytest.approx(1.0, abs=1e-6) or u[-1] <= 1.0
    assert u[-1] > 0.95


def test_scaling_curve_latency_penalty_slows_ramp():
    """Larger p0 -> slower approach to saturation."""
    u_fast = ecm.scaling_curve(0.3, 0.3, 1.0, 10, p0_factor=0.0)
    u_slow = ecm.scaling_curve(0.3, 0.3, 1.0, 10, p0_factor=1.0)
    assert u_fast[4] > u_slow[4]
    # With no penalty the ramp is exactly linear until saturation.
    assert u_fast[1] == pytest.approx(0.6)


def test_bandwidth_vs_cores_saturates_at_bs():
    spec = table2.kernel("DDOT2")
    bw = ecm.bandwidth_vs_cores(spec, "CLX", 20)
    assert bw[0] == pytest.approx(spec.single_core_bw("CLX"))
    assert bw[-1] <= spec.bs["CLX"] * 1.0001
    assert bw[-1] > 0.9 * spec.bs["CLX"]
