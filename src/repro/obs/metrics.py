"""Named counters, gauges, and histograms on one process-wide registry.

Spans (:mod:`repro.obs.trace`) answer *where time went*; metrics answer
*how much of what happened* — jit-cache hits per shape bucket, solver
iterations, deadlocked scenarios — as cheap always-on aggregates that
survive even when tracing is off.

This registry absorbs and supersedes the private ``_STATS`` dict that
``core/backend.py`` used to keep: the backend's hit/miss counters are
now ordinary instruments here, and ``backend.clear_jit_cache()`` resets
the whole registry so tests cannot leak counts across cases.

Naming scheme (see docs/observability.md): dotted lowercase
``layer.noun.verb`` names, with variable dimensions (shape buckets,
backends) as *labels*, never baked into the name::

    from repro.obs import metrics

    metrics.counter("backend.jit.miss", key="sharing.solve_batch").inc()
    metrics.gauge("sharing.fp.residual").set(3.2e-13)
    metrics.histogram("backend.jit.compile_s").observe(0.41)

Instruments are get-or-create on every call — handles looked up in hot
paths stay valid, but after :func:`reset` a cached handle is orphaned
(its updates vanish from snapshots), so hot paths should re-look-up
rather than cache across cache-clear boundaries.  Lookups are one dict
access under one lock; measured cost is tens of nanoseconds.
"""

from __future__ import annotations

import math
import threading

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
    "counter", "gauge", "histogram", "snapshot", "reset",
]


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counters only go up; got inc({n})")
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value

    def to_dict(self) -> dict:
        return {"value": self._value}


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = None
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value

    def add(self, delta: float) -> None:
        with self._lock:
            self._value = (self._value or 0) + delta

    @property
    def value(self):
        return self._value

    def to_dict(self) -> dict:
        return {"value": self._value}


class Histogram:
    """Streaming summary: count / sum / min / max / mean / stddev.

    Keeps moments rather than samples so memory stays O(1) no matter
    how hot the probe is; exporters that need percentiles should use
    span durations from the trace buffer instead.
    """

    __slots__ = ("_count", "_sum", "_sumsq", "_min", "_max", "_lock")

    def __init__(self):
        self._count = 0
        self._sum = 0.0
        self._sumsq = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self._count += 1
            self._sum += v
            self._sumsq += v * v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def to_dict(self) -> dict:
        if not self._count:
            return {"count": 0, "sum": 0.0, "min": None, "max": None,
                    "mean": None, "stddev": None}
        mean = self._sum / self._count
        var = max(0.0, self._sumsq / self._count - mean * mean)
        return {"count": self._count, "sum": self._sum, "min": self._min,
                "max": self._max, "mean": mean, "stddev": math.sqrt(var)}


class Registry:
    """Process-wide instrument store keyed by (name, sorted labels)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict = {}

    def _get(self, cls, name: str, labels: dict):
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls()
                self._instruments[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r}{dict(key[1]) or ''} already registered "
                    f"as {type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def snapshot(self) -> list[dict]:
        """One dict per instrument: name, labels, type, and its stats —
        ndjson-ready rows (sorted for deterministic export)."""
        with self._lock:
            items = sorted(self._instruments.items())
        rows = []
        for (name, labels), inst in items:
            rows.append({"name": name, "labels": dict(labels),
                         "type": type(inst).__name__.lower(),
                         **inst.to_dict()})
        return rows

    def reset(self) -> None:
        """Forget every instrument.  Cached handles become orphans whose
        updates no longer appear in snapshots."""
        with self._lock:
            self._instruments.clear()


REGISTRY = Registry()

# Module-level sugar over the process-wide registry.
counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
snapshot = REGISTRY.snapshot
reset = REGISTRY.reset
