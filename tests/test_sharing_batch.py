"""Batched (vmapped) sharing solver vs. the scalar reference path.

Acceptance gate of the topology PR: the vmapped solver must match the
scalar solver to <= 1e-6 relative error on all Table 2 kernel pairings,
and degenerate scenarios (no groups, one saturated group, all-idle) must
be well-defined.
"""

import numpy as np
import pytest

from repro.core import sharing, table2
from repro.core.sharing import HAVE_JAX, Group

BACKENDS = ["numpy"] + (["jax"] if HAVE_JAX else [])

UTIL_MODES = ["recursion", "queue", 0.7]


def _table2_pair_scenarios(arch, n_a=5, n_b=5):
    names = sorted(table2.TABLE2)
    scens = []
    for ka in names:
        for kb in names:
            scens.append([Group.of(table2.kernel(ka), arch, n_a),
                          Group.of(table2.kernel(kb), arch, n_b)])
    return scens


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("arch", table2.ARCHS)
def test_batch_matches_scalar_on_table2_pairings(backend, arch):
    """<= 1e-6 relative agreement on every Table 2 x Table 2 pairing."""
    scens = _table2_pair_scenarios(arch)
    batch = sharing.predict_batch(scens, backend=backend)
    for i, gs in enumerate(scens):
        ref = sharing.predict(gs)
        assert batch.b_overlap[i] == pytest.approx(ref.b_overlap, rel=1e-6)
        for j in range(2):
            assert batch.alphas[i, j] == pytest.approx(
                ref.alphas[j], rel=1e-6)
            assert batch.bw_group[i, j] == pytest.approx(
                ref.bw_group[j], rel=1e-6)
            assert batch.bw_per_core[i, j] == pytest.approx(
                ref.bw_per_core[j], rel=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("util", UTIL_MODES)
def test_batch_matches_scalar_across_modes(backend, util):
    """Agreement holds in every utilization mode, with uneven splits and
    >2 groups."""
    rng = np.random.default_rng(7)
    scens = []
    for _ in range(40):
        g = rng.integers(1, 5)
        scens.append([Group(n=int(rng.integers(0, 12)),
                            f=float(rng.uniform(0.05, 1.0)),
                            bs=float(rng.uniform(20.0, 200.0)))
                      for _ in range(g)])
    batch = sharing.predict_batch(scens, utilization=util, backend=backend)
    for i, gs in enumerate(scens):
        ref = sharing.predict(gs, utilization=util)
        assert batch.total_bw[i] == pytest.approx(
            sum(ref.bw_group), rel=1e-6, abs=1e-12)
        for j in range(len(gs)):
            assert batch.bw_group[i, j] == pytest.approx(
                ref.bw_group[j], rel=1e-6, abs=1e-12)


@pytest.mark.parametrize("backend", BACKENDS)
def test_saturated_flag(backend):
    scens = [[Group(n=2, f=0.2, bs=100.0), Group(n=2, f=0.4, bs=80.0)]]
    batch = sharing.predict_batch(scens, saturated=True, backend=backend)
    ref = sharing.predict(scens[0], saturated=True)
    assert batch.util[0] == pytest.approx(1.0)
    assert batch.total_bw[0] == pytest.approx(ref.total_bw, rel=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_degenerate_no_active_groups(backend):
    """n = 0 everywhere (all-idle domain): zero bandwidth, no NaNs."""
    batch = sharing.solve_batch([[0, 0]], [[0.3, 0.5]], [[100.0, 90.0]],
                                backend=backend)
    assert batch.b_overlap[0] == 0.0
    assert batch.total_bw[0] == 0.0
    assert not np.isnan(batch.alphas).any()
    assert not np.isnan(batch.bw_per_core).any()


@pytest.mark.parametrize("backend", BACKENDS)
def test_degenerate_single_saturated_group(backend):
    """One group past its saturation knee attains exactly b_s (queue
    law), matching the scalar path."""
    spec = table2.kernel("DDOT2")
    f, bs = spec.f["CLX"], spec.bs["CLX"]
    n_sat = int(1 / f) + 5
    batch = sharing.solve_batch([[n_sat]], [[f]], [[bs]],
                                utilization="queue", backend=backend)
    assert batch.total_bw[0] == pytest.approx(bs, rel=1e-12)
    ref = sharing.predict([Group.of(spec, "CLX", n_sat)],
                          utilization="queue")
    assert batch.total_bw[0] == pytest.approx(ref.total_bw, rel=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_padding_groups_are_neutral(backend):
    """Appending n=0 padding columns never changes the live groups."""
    n = [[3, 5]]
    f = [[0.3, 0.2]]
    bs = [[60.0, 70.0]]
    plain = sharing.solve_batch(n, f, bs, backend=backend)
    padded = sharing.solve_batch([[3, 5, 0, 0]], [[0.3, 0.2, 0.9, 0.1]],
                                 [[60.0, 70.0, 500.0, 1.0]],
                                 backend=backend)
    np.testing.assert_allclose(padded.bw_group[0, :2], plain.bw_group[0],
                               rtol=1e-12)
    np.testing.assert_allclose(padded.bw_group[0, 2:], 0.0)


def test_empty_group_list_scalar():
    pred = sharing.predict([])
    assert pred.bw_group == ()
    assert pred.b_overlap == 0.0


@pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
def test_jax_and_numpy_backends_agree():
    rng = np.random.default_rng(3)
    n = rng.integers(0, 20, size=(64, 4)).astype(float)
    f = rng.uniform(0.01, 1.0, size=(64, 4))
    bs = rng.uniform(10.0, 300.0, size=(64, 4))
    for util in UTIL_MODES:
        a = sharing.solve_batch(n, f, bs, utilization=util, backend="numpy")
        b = sharing.solve_batch(n, f, bs, utilization=util, backend="jax")
        np.testing.assert_allclose(a.bw_group, b.bw_group, rtol=1e-9)
        np.testing.assert_allclose(a.util, b.util, rtol=1e-9)


def test_scenario_round_trip_keeps_names():
    """Named groups survive groups_to_arrays -> solve_batch -> scenario():
    the batch path must not silently strip kernel labels."""
    scens = [
        [Group(n=4, f=0.3, bs=90.0, name="DDOT2"),
         Group(n=6, f=0.8, bs=70.0, name="DAXPY")],
        [Group(n=2, f=0.5, bs=110.0, name="STREAM")],
    ]
    batch = sharing.predict_batch(scens)
    for i, gs in enumerate(scens):
        back = batch.scenario(i)
        assert [g.name for g in back.groups] == [g.name for g in gs]
        assert [g.n for g in back.groups] == [g.n for g in gs]
    # Padding columns (scenario 1 has one group) stay dropped.
    assert len(batch.scenario(1).groups) == 1


def test_groups_to_arrays_returns_padded_names():
    scens = [[Group(n=1, f=0.2, bs=50.0, name="a")],
             [Group(n=2, f=0.3, bs=60.0, name="b"),
              Group(n=3, f=0.4, bs=70.0, name="c")]]
    n, f, bs, names = sharing.groups_to_arrays(scens)
    assert names == (("a", ""), ("b", "c"))
    assert n.shape == (2, 2)


def test_solve_batch_names_shape_mismatch_raises():
    with pytest.raises(ValueError, match="names"):
        sharing.solve_batch([[1, 2]], [[0.5, 0.5]], [[10.0, 20.0]],
                            names=(("x",),))


def test_shape_mismatch_raises():
    with pytest.raises(ValueError, match="shape mismatch"):
        sharing.solve_batch([[1, 2]], [[0.5]], [[100.0, 90.0]])


def test_unknown_backend_and_mode():
    with pytest.raises(ValueError, match="backend"):
        sharing.solve_batch([[1]], [[0.5]], [[10.0]], backend="tpu")
    with pytest.raises(ValueError, match="utilization"):
        sharing.solve_batch([[1]], [[0.5]], [[10.0]], utilization="magic")
