from .pipeline import SyntheticLM, HostLoader, make_batch_specs

__all__ = ["SyntheticLM", "HostLoader", "make_batch_specs"]
