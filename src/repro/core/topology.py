"""Contention-domain topology: machines as trees of memory domains.

The paper's sharing model (core/sharing.py, Eqs. 4–5) arbitrates bandwidth
on *one* memory contention domain.  Real machines have several: a
dual-socket Cascade Lake node has two, a dual-socket Rome in NPS4 mode has
eight ccNUMA quadrants, a TPU v5e pod slice has one HBM interface per chip.
Kerncraft-style automated analysis (Hammer et al., arXiv:1509.03778) and the
cache-topology study behind LIKWID (Treibig et al., arXiv:0910.4865) both
show that getting topology wrong is where single-domain models break down.

This module describes a machine as a tree — interior :class:`TopologyNode`
levels (node, socket, package) over leaf :class:`ContentionDomain` objects —
and solves a *placement* of thread groups onto leaves by running the Eq. 4–5
arbitration independently per domain (memory controllers of different
ccNUMA domains do not contend with each other; cross-domain traffic is out
of scope exactly as in the paper) and aggregating the results.

The per-domain solves go through the batched array solver
(:func:`repro.core.sharing.solve_batch`), so a topology solve is one
vectorized call regardless of how many domains are populated.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

import numpy as np

from .machine import (BDW1, BDW2, CLX, ROME, TPU_V5E, MachineModel,
                      TpuModel)
from .sharing import (BatchSharePrediction, Group, PlacedBatchSharePrediction,
                      SharePrediction, groups_to_arrays, solve_batch,
                      solve_placed_batch)


# ---------------------------------------------------------------------------
# Tree description
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ContentionDomain:
    """Leaf of the tree: one memory interface arbitrated by Eqs. 4–5.

    ``n_cores`` is the domain's capacity — cores on a ccNUMA domain, or
    concurrent HBM streams (compute loads, DMA prefetch, collective drains)
    on a TPU chip.  ``machine`` / ``tpu`` carry the hardware description the
    domain was derived from, when there is one; they are not needed by the
    solver itself (groups bring their own ``f`` and ``b_s``).
    """

    name: str
    n_cores: int
    machine: MachineModel | None = None
    tpu: TpuModel | None = None

    @property
    def saturated_bw_gbs(self) -> float | None:
        """Read-write saturation envelope of the domain, if known."""
        if self.machine is not None:
            return self.machine.saturated_bw_gbs["read_write"]
        if self.tpu is not None:
            return self.tpu.hbm_bw_gbs
        return None


@dataclasses.dataclass(frozen=True)
class TopologyNode:
    """Interior node: a node, socket, or package grouping domains."""

    name: str
    children: tuple["TopologyNode | ContentionDomain", ...]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A machine as a tree of contention domains."""

    root: TopologyNode

    @property
    def name(self) -> str:
        return self.root.name

    @property
    def domains(self) -> tuple[ContentionDomain, ...]:
        """All leaves, depth-first (stable order used for batching)."""
        out: list[ContentionDomain] = []

        def walk(node: TopologyNode | ContentionDomain) -> None:
            if isinstance(node, ContentionDomain):
                out.append(node)
            else:
                for child in node.children:
                    walk(child)

        walk(self.root)
        return tuple(out)

    @property
    def domain_names(self) -> tuple[str, ...]:
        return tuple(d.name for d in self.domains)

    def domain(self, name: str) -> ContentionDomain:
        for d in self.domains:
            if d.name == name:
                return d
        raise KeyError(
            f"no contention domain {name!r} in topology {self.name!r}; "
            f"available: {list(self.domain_names)}")

    def __contains__(self, name: str) -> bool:
        return any(d.name == name for d in self.domains)

    @property
    def total_cores(self) -> int:
        return sum(d.n_cores for d in self.domains)


# ---------------------------------------------------------------------------
# Placement + solve
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Placed:
    """One thread group pinned to one contention domain."""

    group: Group
    domain: str


@dataclasses.dataclass(frozen=True)
class TopologyPrediction:
    """Per-domain Eq. 4–5 solutions plus cross-domain aggregates.

    ``bw_group`` is ordered like the input placements, so callers can zip
    it against what they passed in regardless of domain structure.
    """

    topology: Topology
    placements: tuple[Placed, ...]
    by_domain: Mapping[str, SharePrediction]
    bw_group: tuple[float, ...]

    @property
    def bw_per_core(self) -> tuple[float, ...]:
        return tuple(b / p.group.n if p.group.n else 0.0
                     for b, p in zip(self.bw_group, self.placements))

    @property
    def total_bw(self) -> float:
        """Aggregate attained bandwidth across every domain [GB/s]."""
        return sum(self.bw_group)

    def domain_bw(self, name: str) -> float:
        """Attained bandwidth on one domain (0 for an idle domain)."""
        return sum(self.by_domain[name].bw_group)


def predict_placed(topology: Topology, placements: Sequence[Placed], *,
                   strict: bool = True, **solver_kwargs
                   ) -> TopologyPrediction:
    """Solve every populated domain's arbitration in one batched call.

    Each leaf domain is an independent Eq. 4–5 instance; an idle domain
    trivially attains zero bandwidth.  ``strict=True`` rejects placements
    that name unknown domains or overcommit a domain's cores.

    ``solver_kwargs`` (``utilization``, ``saturated``, ``p0_factor``,
    ``backend``) are forwarded to :func:`repro.core.sharing.solve_batch`.
    """
    placements = tuple(placements)
    names = topology.domain_names
    per_domain: dict[str, list[tuple[int, Group]]] = {n: [] for n in names}
    for idx, p in enumerate(placements):
        if p.domain not in per_domain:
            raise KeyError(
                f"placement {idx} names unknown domain {p.domain!r}; "
                f"available: {list(names)}")
        per_domain[p.domain].append((idx, p.group))

    if strict:
        for name in names:
            used = sum(g.n for _, g in per_domain[name])
            cap = topology.domain(name).n_cores
            if used > cap:
                raise ValueError(
                    f"domain {name!r} overcommitted: {used} threads placed "
                    f"on {cap} cores (pass strict=False to allow)")

    populated = [n for n in names if per_domain[n]]
    by_domain: dict[str, SharePrediction] = {}
    bw_flat: list[float] = [0.0] * len(placements)

    if populated:
        scenarios = [[g for _, g in per_domain[n]] for n in populated]
        batch = solve_batch(*groups_to_arrays(scenarios), **solver_kwargs)
        for row, name in enumerate(populated):
            entries = per_domain[name]
            groups = tuple(g for _, g in entries)
            bws = tuple(float(batch.bw_group[row, j])
                        for j in range(len(entries)))
            by_domain[name] = SharePrediction(
                groups=groups,
                b_overlap=float(batch.b_overlap[row]),
                alphas=tuple(float(batch.alphas[row, j])
                             for j in range(len(entries))),
                bw_group=bws)
            for (idx, _), bw in zip(entries, bws):
                bw_flat[idx] = bw
    for name in names:
        if name not in by_domain:
            by_domain[name] = SharePrediction(
                groups=(), b_overlap=0.0, alphas=(), bw_group=())

    return TopologyPrediction(topology=topology, placements=placements,
                              by_domain=by_domain, bw_group=tuple(bw_flat))


# ---------------------------------------------------------------------------
# Placement-batched solve: B placements on one topology, one flattened call
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlacedGrid:
    """B ragged placements packed onto a common ``(B, D, K)`` grid.

    ``D`` is the topology's full leaf count (depth-first, matching
    :attr:`Topology.domains`); ``K`` is the largest per-domain group count
    across the whole batch.  ``slots[b][j]`` gives the ``(d, k)`` cell
    scenario *b*'s *j*-th placement landed in, so results on the grid can
    be read back in input order.
    """

    topology: Topology
    placements: tuple[tuple[Placed, ...], ...]
    n: np.ndarray     # (B, D, K)
    f: np.ndarray     # (B, D, K)
    bs: np.ndarray    # (B, D, K)
    mask: np.ndarray  # (B, D, K) bool, True = occupied
    slots: tuple[tuple[tuple[int, int], ...], ...]

    def __len__(self) -> int:
        return len(self.placements)


def pack_placed(topology: Topology,
                placements_batch: Sequence[Sequence[Placed]], *,
                strict: bool = True) -> PlacedGrid:
    """Pad B heterogeneous placements to one occupancy-masked grid.

    Groups keep their placement order within each domain (the same order
    :func:`predict_placed` packs them in, so grid solves are bit-for-bit
    comparable).  ``strict=True`` applies the same unknown-domain and
    overcommit checks as :func:`predict_placed`, per scenario.
    """
    placements_batch = tuple(tuple(p) for p in placements_batch)
    names = topology.domain_names
    dom_index = {n: i for i, n in enumerate(names)}
    caps = {n: topology.domain(n).n_cores for n in names}
    B, D = len(placements_batch), len(names)

    per_scenario: list[dict[int, list[tuple[int, Group]]]] = []
    K = 1
    for b, placements in enumerate(placements_batch):
        per_domain: dict[int, list[tuple[int, Group]]] = {}
        used = dict.fromkeys(names, 0.0)
        for idx, p in enumerate(placements):
            if p.domain not in dom_index:
                raise KeyError(
                    f"scenario {b}: placement {idx} names unknown domain "
                    f"{p.domain!r}; available: {list(names)}")
            per_domain.setdefault(dom_index[p.domain], []).append(
                (idx, p.group))
            used[p.domain] += p.group.n
        if strict:
            for name in names:
                if used[name] > caps[name]:
                    raise ValueError(
                        f"scenario {b}: domain {name!r} overcommitted: "
                        f"{used[name]:g} threads placed on {caps[name]} "
                        f"cores (pass strict=False to allow)")
        per_scenario.append(per_domain)
        K = max(K, *(len(v) for v in per_domain.values()), 1)

    n = np.zeros((B, D, K))
    f = np.zeros((B, D, K))
    bs = np.zeros((B, D, K))
    mask = np.zeros((B, D, K), dtype=bool)
    slots: list[tuple[tuple[int, int], ...]] = []
    for b, per_domain in enumerate(per_scenario):
        slot_of: dict[int, tuple[int, int]] = {}
        for d, entries in per_domain.items():
            for k, (idx, g) in enumerate(entries):
                n[b, d, k] = g.n
                f[b, d, k] = g.f
                bs[b, d, k] = g.bs
                mask[b, d, k] = True
                slot_of[idx] = (d, k)
        slots.append(tuple(slot_of[j]
                           for j in range(len(placements_batch[b]))))
    return PlacedGrid(topology=topology, placements=placements_batch,
                      n=n, f=f, bs=bs, mask=mask, slots=tuple(slots))


@dataclasses.dataclass(frozen=True)
class TopologyBatchPrediction:
    """B placed-topology solutions from one flattened grid solve.

    ``scenario(i)`` materializes the i-th result as the
    :class:`TopologyPrediction` a lone :func:`predict_placed` call would
    have returned — on the numpy path bit-for-bit, because padded grid
    rows and trailing zero lanes are exactly neutral.
    """

    grid: PlacedGrid
    shares: PlacedBatchSharePrediction

    def __len__(self) -> int:
        return len(self.grid)

    @property
    def topology(self) -> Topology:
        return self.grid.topology

    @property
    def total_bw(self) -> np.ndarray:
        """(B,) aggregate attained bandwidth per scenario [GB/s]."""
        return self.shares.total_bw

    @property
    def bw_group(self) -> tuple[tuple[float, ...], ...]:
        """Per scenario, attained bandwidths in input placement order."""
        return tuple(
            tuple(float(self.shares.bw_group[b, d, k])
                  for d, k in self.grid.slots[b])
            for b in range(len(self)))

    def _group_at(self, i: int, j: int) -> Group:
        """Input placement j's group, with numbers read back from the
        solved grid — so ``plan.run(f=..., cores=...)`` number swaps
        show up in materialized results, not just in the arrays."""
        d, k = self.grid.slots[i][j]
        g = self.grid.placements[i][j].group
        n_, f_, bs_ = (float(self.shares.n[i, d, k]),
                       float(self.shares.f[i, d, k]),
                       float(self.shares.bs[i, d, k]))
        if (g.n, g.f, g.bs) == (n_, f_, bs_):
            return g
        return dataclasses.replace(g, n=int(n_), f=f_, bs=bs_)

    def scenario(self, i: int) -> TopologyPrediction:
        """The i-th solution, shaped exactly like :func:`predict_placed`."""
        placements = tuple(
            dataclasses.replace(p, group=self._group_at(i, j))
            for j, p in enumerate(self.grid.placements[i]))
        names = self.topology.domain_names
        by_domain: dict[str, SharePrediction] = {}
        slot_to_idx = {s: j for j, s in enumerate(self.grid.slots[i])}
        for d, name in enumerate(names):
            ks = [k for k in range(self.grid.mask.shape[2])
                  if self.grid.mask[i, d, k]]
            if not ks:
                by_domain[name] = SharePrediction(
                    groups=(), b_overlap=0.0, alphas=(), bw_group=())
                continue
            by_domain[name] = SharePrediction(
                groups=tuple(placements[slot_to_idx[(d, k)]].group
                             for k in ks),
                b_overlap=float(self.shares.b_overlap[i, d]),
                alphas=tuple(float(self.shares.alphas[i, d, k])
                             for k in ks),
                bw_group=tuple(float(self.shares.bw_group[i, d, k])
                               for k in ks))
        return TopologyPrediction(
            topology=self.topology, placements=placements,
            by_domain=by_domain,
            bw_group=tuple(float(self.shares.bw_group[i, d, k])
                           for d, k in self.grid.slots[i]))


def predict_placed_batch(topology: Topology,
                         placements_batch: Sequence[Sequence[Placed]], *,
                         strict: bool = True, **solver_kwargs
                         ) -> TopologyBatchPrediction:
    """Solve B placements of one topology as a single flattened call.

    Packs the batch to a common ``(B, D, K)`` grid
    (:func:`pack_placed`) and runs every domain of every scenario
    through one :func:`repro.core.sharing.solve_placed_batch` — the
    grid flattens to ``(B·D, K)`` rows, so backend dispatch and the
    process-wide jit cache see the same power-of-two buckets the
    unplaced batched path uses.  ``solver_kwargs`` (``utilization``,
    ``saturated``, ``p0_factor``, ``backend``, ``jax_cutoff``,
    ``chunk``) forward to the solver.
    """
    grid = pack_placed(topology, placements_batch, strict=strict)
    shares = solve_placed_batch(grid.n, grid.f, grid.bs, mask=grid.mask,
                                **solver_kwargs)
    return TopologyBatchPrediction(grid=grid, shares=shares)


def predict_single_domain(groups: Sequence[Group],
                          domain: ContentionDomain | None = None,
                          **solver_kwargs) -> SharePrediction:
    """Single-domain compatibility wrapper: the paper's original scenario
    as a one-leaf topology solve.  With ``domain=None`` an unbounded
    anonymous domain is used (capacity checks off), which reproduces the
    historical ``sharing.predict`` behavior exactly."""
    if domain is None:
        domain = ContentionDomain(
            "domain0", n_cores=sum(int(g.n) for g in groups))
    topo = Topology(TopologyNode(domain.name, (domain,)))
    pred = predict_placed(
        topo, [Placed(g, domain.name) for g in groups], **solver_kwargs)
    return pred.by_domain[domain.name]


def spread_counts(total: int, n_domains: int) -> tuple[int, ...]:
    """Block-distribute ``total`` threads over ``n_domains`` domains
    (first domains get the remainder), the usual OpenMP ``places=sockets``
    convention."""
    base, rem = divmod(total, n_domains)
    return tuple(base + (1 if i < rem else 0) for i in range(n_domains))


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------


def single_domain(machine: MachineModel) -> Topology:
    """One ccNUMA domain — the paper's measurement setting (Table I)."""
    leaf = ContentionDomain(f"{machine.name}/d0",
                            n_cores=machine.cores_per_domain,
                            machine=machine)
    return Topology(TopologyNode(machine.name, (leaf,)))


def multi_socket(machine: MachineModel, n_sockets: int = 2, *,
                 domains_per_socket: int = 1) -> Topology:
    """A multi-socket node of identical sockets, each split into
    ``domains_per_socket`` ccNUMA domains (NPS4 Rome: 4)."""
    sockets = []
    for s in range(n_sockets):
        leaves = tuple(
            ContentionDomain(f"{machine.name}/s{s}/d{d}",
                             n_cores=machine.cores_per_domain,
                             machine=machine)
            for d in range(domains_per_socket))
        sockets.append(TopologyNode(f"{machine.name}/s{s}", leaves))
    name = f"{machine.name}-{n_sockets}S"
    if domains_per_socket > 1:
        name += f"-NPS{domains_per_socket}"
    return Topology(TopologyNode(name, tuple(sockets)))


def tpu_pod(tpu: TpuModel = TPU_V5E, n_chips: int = 4, *,
            streams_per_chip: int = 8) -> Topology:
    """A pod slice: one HBM contention domain per chip.  ``n_cores`` is the
    number of concurrent HBM stream agents modelled per chip (compute-phase
    loads, DMA prefetch, collective send/recv drains)."""
    leaves = tuple(
        ContentionDomain(f"{tpu.name}/chip{c}", n_cores=streams_per_chip,
                         tpu=tpu)
        for c in range(n_chips))
    return Topology(TopologyNode(f"{tpu.name}-pod{n_chips}", leaves))


# Ready-made machines.  x86 names follow the paper's Table I; the -2S
# variants are the dual-socket nodes the paper's HPCG runs used, and
# ROME-2S-NPS4 is the eight-quadrant layout of a dual Rome node.
PRESETS: dict[str, "Topology"] = {}


def _register(topo: Topology) -> Topology:
    PRESETS[topo.name] = topo
    return topo


for _m in (BDW1, BDW2, CLX, ROME):
    _register(single_domain(_m))
_register(multi_socket(BDW1, 2))
_register(multi_socket(BDW2, 2))
_register(multi_socket(CLX, 2))
_register(multi_socket(ROME, 2, domains_per_socket=4))
_register(tpu_pod(TPU_V5E, 4))
_register(tpu_pod(TPU_V5E, 8))


def preset(name: str) -> Topology:
    """Look up a ready-made topology by name (see :data:`PRESETS`)."""
    try:
        return PRESETS[name]
    except KeyError:
        from ..api.registry import unknown_key_error
        raise unknown_key_error("topology preset", name, PRESETS) from None
