"""THE PAPER'S CONTRIBUTION: the analytic bandwidth-sharing model (Eqs. 4–5).

Given groups of threads concurrently executing different memory-bound loop
kernels on one contention domain, predict the memory-bandwidth share each
group (and each core) attains.  Inputs per group: thread count ``n``, memory
request fraction ``f``, and homogeneous saturated bandwidth ``b_s``.

The model generalizes naturally from the paper's two groups to N groups —
the request-proportional arbitration (Eq. 5) and the thread-weighted
saturation envelope (Eq. 4) are both linear in the groups.  We use the
N-group form throughout (the desync simulator routinely has >2 distinct
kernels in flight).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .ecm import scaling_curve
from .table2 import KernelSpec


@dataclasses.dataclass(frozen=True)
class Group:
    """One group of threads all executing the same kernel."""

    n: int          # number of threads
    f: float        # memory request fraction of the kernel (Eq. 2/3)
    bs: float       # saturated bandwidth of the kernel, homogeneous run
    name: str = ""

    @staticmethod
    def of(kernel: KernelSpec, arch: str, n: int) -> "Group":
        return Group(n=n, f=kernel.f[arch], bs=kernel.bs[arch],
                     name=kernel.name)


@dataclasses.dataclass(frozen=True)
class SharePrediction:
    groups: tuple[Group, ...]
    b_overlap: float            # Eq. 4 saturation envelope [GB/s]
    alphas: tuple[float, ...]   # Eq. 5 request shares, sum to 1
    bw_group: tuple[float, ...]  # attained bandwidth per group [GB/s]

    @property
    def bw_per_core(self) -> tuple[float, ...]:
        return tuple(b / g.n if g.n else 0.0
                     for b, g in zip(self.bw_group, self.groups))

    @property
    def total_bw(self) -> float:
        return sum(self.bw_group)


def overlapped_saturated_bw(groups: Sequence[Group]) -> float:
    """Paper Eq. (4): thread-weighted mean of homogeneous saturated bws."""
    n_tot = sum(g.n for g in groups)
    if n_tot == 0:
        return 0.0
    return sum(g.n * g.bs for g in groups) / n_tot


def request_shares(groups: Sequence[Group]) -> tuple[float, ...]:
    """Paper Eq. (5): share of requests (hence bandwidth) per group."""
    weights = [g.n * g.f for g in groups]
    tot = sum(weights)
    if tot == 0.0:
        return tuple(0.0 for _ in groups)
    return tuple(w / tot for w in weights)


def predict(groups: Sequence[Group], *, saturated: bool | None = None,
            utilization: str | float = "recursion",
            p0_factor: float = 0.5) -> SharePrediction:
    """Bandwidth share per group.

    The envelope is ``U(n_t; f̄) · b(mix)``: the Eq. 4 mix envelope scaled by
    the interface utilization at the *mean* request fraction
    ``f̄ = Σ nᵢfᵢ / n_t``.  At saturation U → 1 and the model is exactly
    Eqs. 4–5; below saturation each group's share degrades to its demand
    (paper Sect. IV: the model "can also be applied to the nonsaturated
    case").

    ``utilization`` selects the sub-saturation law:
      * ``"recursion"`` — the paper's simplified latency-penalty recursion
        (Hofmann et al.), penalty ``p0 = p0_factor · T_Mem`` (paper uses
        p0_factor = 1/2; the full model fits it per machine).  Soft knee,
        matches real hardware (paper Fig. 7).
      * ``"queue"`` — ideal work-conserving interface, ``U = min(1, f̄·n_t)``.
        Hard knee, matches the idealized queue instrument (core/memsim.py).
      * a float — externally calibrated utilization.
    ``saturated=True`` forces U = 1.
    """
    groups = tuple(groups)
    b = overlapped_saturated_bw(groups)
    alphas = request_shares(groups)
    n_tot = sum(g.n for g in groups)

    util = 1.0
    if saturated is not True and n_tot > 0:
        f_mean = sum(g.n * g.f for g in groups) / n_tot
        if isinstance(utilization, (int, float)):
            util = float(utilization)
        elif utilization == "queue":
            util = min(1.0, f_mean * n_tot)
        elif f_mean > 0:
            util = scaling_curve(f_mean, t_mem=f_mean, t_ecm=1.0,
                                 n_max=n_tot, p0_factor=p0_factor)[n_tot - 1]
    bw = tuple(a * util * b for a in alphas)

    return SharePrediction(groups=groups, b_overlap=b, alphas=alphas,
                           bw_group=bw)


def pair(kernel_a: KernelSpec, kernel_b: KernelSpec, arch: str,
         n_a: int, n_b: int, **kwargs) -> SharePrediction:
    """Convenience: the paper's two-kernel scenario on architecture ``arch``."""
    return predict([Group.of(kernel_a, arch, n_a),
                    Group.of(kernel_b, arch, n_b)], **kwargs)


def gain_vs_self(kernel_a: KernelSpec, kernel_b: KernelSpec, arch: str,
                 n_each: int) -> float:
    """Paper Fig. 9 bar height: relative bandwidth gain/loss of kernel A when
    paired with B (each on ``n_each`` cores), normalized to A self-paired."""
    mixed = pair(kernel_a, kernel_b, arch, n_each, n_each)
    homo = pair(kernel_a, kernel_a, arch, n_each, n_each)
    return mixed.bw_group[0] / homo.bw_group[0]


def runtime(groups: Sequence[Group], work_bytes: Sequence[float]
            ) -> tuple[float, ...]:
    """Predicted wall time per group to move ``work_bytes`` at the shared
    bandwidth (bytes / (bw per group)).  Used by the desync simulator."""
    pred = predict(groups)
    return tuple(
        wb / (bw * 1e9) if bw > 0 else float("inf")
        for wb, bw in zip(work_bytes, pred.bw_group)
    )
