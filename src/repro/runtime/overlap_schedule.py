"""Overlap scheduler: decides compute/collective co-scheduling using the
paper's bandwidth-sharing model (core/overlap.py).

Given the roofline decomposition of a training step (from the dry-run HLO or
from analytic estimates), it answers:
  * should the gradient reduce-scatter overlap the backward pass at all?
  * if so, into how many buckets should it be split?
  * what is the predicted step time under each policy?

The classical heuristic ("always overlap, assume it's free") over-predicts
speedup when the collective's HBM drain contends with the backward matmuls'
streams — exactly the effect the paper models with Eqs. 4–5.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from ..api import Scenario, ScenarioBatch
from ..api import compile as compile_plan
from ..configs.base import ModelConfig
from ..core.hlo import RooflineTerms
from ..core.machine import TPU_V5E, TpuModel
from ..core.overlap import Phase, best_bucket_count, overlap_pair
from ..core.topology import Topology, tpu_pod


@dataclasses.dataclass(frozen=True)
class OverlapPlan:
    overlap: bool
    n_buckets: int
    t_serial: float
    t_planned: float
    t_naive_roofline: float     # what "perfect overlap" would promise

    @property
    def predicted_gain(self) -> float:
        return self.t_serial / self.t_planned if self.t_planned else 1.0


def plan_gradient_overlap(terms: RooflineTerms, *,
                          backward_frac: float = 2 / 3,
                          tpu: TpuModel = TPU_V5E) -> OverlapPlan:
    """Build the overlap plan from a step's roofline terms.

    ``backward_frac``: share of compute/HBM belonging to the backward pass
    (2/3 for standard fwd+bwd without remat; remat shifts it higher).
    """
    bwd = Phase("bwd",
                flops=terms.flops * backward_frac,
                hbm_bytes=terms.hbm_bytes * backward_frac)
    # The gradient collective: its wire bytes on ICI, and an HBM drain of
    # the same magnitude (send buffers are read + recv written once).
    coll = Phase("grad_rs",
                 ici_bytes=terms.wire_bytes,
                 hbm_bytes=2.0 * terms.wire_bytes)
    t_serial = bwd.t_solo(tpu) + coll.t_solo(tpu)
    nb, t_planned = best_bucket_count(bwd, coll, tpu=tpu)
    pred = overlap_pair(bwd, coll, tpu)
    return OverlapPlan(
        overlap=nb > 0 and t_planned < t_serial * 0.995,
        n_buckets=max(nb, 1),
        t_serial=t_serial,
        t_planned=min(t_planned, t_serial),
        t_naive_roofline=pred.t_naive,
    )


@dataclasses.dataclass(frozen=True)
class PodOverlapPlan:
    """Per-chip overlap plans across a pod slice: each chip's HBM domain is
    independent, so the step time is gated by the slowest chip."""

    topology: Topology
    by_chip: Mapping[str, OverlapPlan]

    @property
    def t_step(self) -> float:
        """Data-parallel step time: the allreduce gates on the slowest
        chip's planned time."""
        return max(p.t_planned for p in self.by_chip.values())

    @property
    def straggler_chip(self) -> str:
        return max(self.by_chip, key=lambda c: self.by_chip[c].t_planned)


def plan_pod_overlap(terms: RooflineTerms, *,
                     topology: Topology | None = None,
                     chip_load: Sequence[float] | None = None,
                     backward_frac: float = 2 / 3,
                     tpu: TpuModel = TPU_V5E) -> PodOverlapPlan:
    """Plan gradient overlap per chip of a pod topology.

    Each leaf domain of ``topology`` (default: a 4-chip v5e pod from
    :func:`repro.core.topology.tpu_pod`) is planned independently —
    contention domains do not interact, so a straggling chip changes only
    its own plan.  ``chip_load`` scales each chip's compute/HBM work
    (data-parallel imbalance, e.g. ragged batch shards); default uniform.
    """
    topo = topology if topology is not None else tpu_pod(tpu)
    chips = topo.domain_names
    load = tuple(chip_load) if chip_load is not None else (1.0,) * len(chips)
    if len(load) != len(chips):
        raise ValueError(
            f"chip_load has {len(load)} entries for {len(chips)} chips")
    by_chip = {}
    for chip, scale in zip(chips, load):
        scaled = dataclasses.replace(
            terms,
            t_compute=terms.t_compute * scale,
            t_memory=terms.t_memory * scale,
            flops=terms.flops * scale,
            hbm_bytes=terms.hbm_bytes * scale)
        by_chip[chip] = plan_gradient_overlap(
            scaled, backward_frac=backward_frac, tpu=tpu)
    return PodOverlapPlan(topology=topo, by_chip=by_chip)


# ---------------------------------------------------------------------------
# Batched candidate evaluation via the desync engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PodPlanEvaluation:
    """Simulated outcome of one candidate per-chip load assignment.

    With a noise ensemble (``evaluate_pod_plans(..., ensemble=E)``)
    ``t_step`` and ``bwd_spread`` are means over the candidate's E
    members and ``t_step_worst`` is the slowest member — rank on it to
    pick plans robust to launch jitter, not just fast on the noiseless
    trace.
    """

    chip_load: tuple[float, ...]
    t_step: float        # makespan: gradient allreduce gates on all chips
    bwd_spread: float    # spread of backward-pass finish times (desync)
    n_members: int = 1
    t_step_worst: float = 0.0

    def __post_init__(self):
        if self.t_step_worst == 0.0:
            object.__setattr__(self, "t_step_worst", self.t_step)

    @property
    def balanced(self) -> bool:
        return self.bwd_spread < 0.05 * self.t_step


def evaluate_pod_plans(terms: RooflineTerms,
                       candidate_loads: Sequence[Sequence[float]], *,
                       topology: Topology | None = None,
                       backward_frac: float = 2 / 3,
                       tpu: TpuModel = TPU_V5E,
                       backend: str = "numpy",
                       noise_s: float = 0.0,
                       seed: int = 0,
                       ensemble: int = 1
                       ) -> list[PodPlanEvaluation]:
    """Evaluate B candidate pod plans as **one** batched desync run.

    Each candidate assigns a load factor to every chip (ragged batch
    shards, re-sharding proposals, straggler mitigation plans).  Per chip
    the step is: backward-pass HBM work (scaled by its load), the gradient
    allreduce (ICI wire time; the global sync point), then the collective's
    HBM drain.  Chips live on their own HBM contention domains, so a
    candidate's step time emerges from the simulated dynamics — a lagging
    chip delays the allreduce for everyone, exactly the effect
    :meth:`PodOverlapPlan.t_step` approximates analytically.

    ``noise_s`` adds per-chip exponential launch jitter with that mean;
    ``ensemble`` simulates each candidate under that many independent
    seeds (streams split per ``(seed, member)``, see
    :func:`repro.api.plan.derive_member_seed`).  The whole candidate ×
    seed grid — B·E rows — still advances as **one** compiled engine
    call; per-candidate statistics are reduced from the fused result.

    Results are returned in candidate order (``min(..., key=t_step)``
    picks the winner).
    """
    topo = topology if topology is not None else tpu_pod(tpu)
    chips = topo.domain_names
    candidate_loads = [tuple(c) for c in candidate_loads]
    for i, load in enumerate(candidate_loads):
        if len(load) != len(chips):
            raise ValueError(
                f"candidate {i} has {len(load)} loads for "
                f"{len(chips)} chips")
    if ensemble < 1:
        raise ValueError(f"ensemble must be >= 1, got {ensemble}")
    if ensemble > 1 and noise_s <= 0.0:
        raise ValueError(
            f"ensemble={ensemble} without noise is {ensemble} identical "
            f"runs; pass noise_s > 0 (per-chip launch jitter mean)")

    bwd = Phase("bwd", flops=terms.flops * backward_frac,
                hbm_bytes=terms.hbm_bytes * backward_frac)
    drain = Phase("grad_drain", hbm_bytes=2.0 * terms.wire_bytes)
    wire_s = Phase("wire", ici_bytes=terms.wire_bytes).times(tpu)[2]
    # A lone Work group attains bw = f·b_s under the recursion law, so a
    # phase's simulated solo duration is hbm_bytes/(f·b_s) = t_solo — the
    # sim reproduces the roofline when nothing contends.
    fbs = {ph.name: (max(ph.request_fraction(tpu), 1e-6), tpu.hbm_bw_gbs)
           for ph in (bwd, drain)}
    scens = []
    for load in candidate_loads:
        sc = (Scenario.on("TPU").ranks(len(chips))
              .using(topo).on_domains(chips)
              .step(fbs["bwd"], [bwd.hbm_bytes * s for s in load],
                    name="bwd", tag="bwd")
              .barrier(cost_s=wire_s, tag="grad_ar"))
        if drain.hbm_bytes > 0:
            sc = sc.step(fbs["grad_drain"], drain.hbm_bytes,
                         name="grad_drain", tag="grad_drain")
        if noise_s > 0.0 or ensemble > 1:
            sc = sc.with_noise(noise_s, seed=seed, ensemble=ensemble)
        scens.append(sc)
    # Compile the candidate × seed grid once (program encoding, noise
    # draws, placement validation, backend selection), then run; the
    # jitted engine for this topology's shape bucket is cached
    # process-wide, so repeated searches on one pod compile once.
    # Plans are compared on t_step; a masked deadlocked candidate would
    # win with a bogus short step, so abort loudly instead.
    plan = compile_plan(ScenarioBatch.of(scens), verb="simulate")
    res = plan.run(t_max=1e6, backend=backend, on_deadlock="raise")
    out = []
    for i, load in enumerate(candidate_loads):
        rows = res.rows_for(i)
        steps = [res.makespan(b) for b in rows]
        spreads = [res.end_spread("bwd", b) for b in rows]
        out.append(PodPlanEvaluation(
            chip_load=load,
            t_step=sum(steps) / len(steps),
            bwd_spread=sum(spreads) / len(spreads),
            n_members=len(rows),
            t_step_worst=max(steps)))
    return out


def best_pod_plan(terms: RooflineTerms,
                  candidate_loads: Sequence[Sequence[float]],
                  **kwargs) -> tuple[int, PodPlanEvaluation]:
    """Index and evaluation of the fastest candidate in one batched run."""
    evals = evaluate_pod_plans(terms, candidate_loads, **kwargs)
    if not evals:
        raise ValueError("no candidate plans given")
    i = min(range(len(evals)), key=lambda j: evals[j].t_step)
    return i, evals[i]
