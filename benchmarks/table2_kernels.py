"""Benchmark for paper Table II: kernel characterization.

For each kernel of the suite: wall-time per call of the jnp implementation
on this host (µs), plus the derived model quantities — element transfers,
code balance, (f, b_s) per architecture, and the single-core bandwidth
``f·b_s`` the sharing model consumes.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import table2
from repro.kernels import ops

N = 1 << 20  # 1M doubles-worth of work (f32 here)

_MAP_INPUTS = {
    "DSCAL": ("dscal", 1), "DAXPY": ("daxpy", 2), "ADD": ("add", 2),
    "STREAM": ("stream", 2), "WAXPBY": ("waxpby", 2), "DCOPY": ("dcopy", 1),
    "Schoenauer": ("schoenauer", 3),
}
_REDUCE_INPUTS = {
    "vectorSUM": ("vectorsum", 1), "DDOT1": ("ddot1", 1),
    "DDOT2": ("ddot2", 2), "DDOT3": ("ddot3", 3),
}


def _time(fn, *args, reps=5) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def rows():
    rng = np.random.default_rng(0)
    arrays = [jnp.asarray(rng.standard_normal(N), jnp.float32)
              for _ in range(3)]
    out = []
    for name, spec in table2.TABLE2.items():
        if name in _MAP_INPUTS:
            op, k = _MAP_INPUTS[name]
            s = jnp.asarray([0.5, 1.5], jnp.float32) if op == "waxpby" \
                else jnp.asarray(0.5, jnp.float32)
            us = _time(lambda *a: ops.stream_map(op, s, *a), *arrays[:k])
        elif name in _REDUCE_INPUTS:
            op, k = _REDUCE_INPUTS[name]
            us = _time(lambda *a: ops.stream_reduce(op, *a), *arrays[:k])
        else:  # stencils
            grid = jnp.asarray(rng.standard_normal((1024, 1024)),
                               jnp.float32)
            if name.endswith("v1"):
                us = _time(lambda g: ops.jacobi_v1(g, 0.25), grid)
            else:
                f = jnp.asarray(rng.standard_normal((1024, 1024)),
                                jnp.float32)
                us = _time(lambda g, ff: ops.jacobi_v2(
                    g, ff, ax=0.4, ay=0.6, b1=2.0, relax=0.9)[0], grid, f)
        bc = spec.code_balance
        derived = ";".join(
            f"{a}:f={spec.f[a]:.3f}:bs={spec.bs[a]:.1f}"
            f":b1={spec.single_core_bw(a):.1f}" for a in table2.ARCHS)
        out.append((f"table2/{name}", us,
                    f"transfers={spec.elem_transfers};Bc={bc:.2f};{derived}"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
